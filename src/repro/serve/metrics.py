"""Serving metrics: latency percentiles, batch occupancy, cache hit rate.

Since PR 6 this is a facade over a general ``repro.obs.registry.
MetricsRegistry`` — every counter, gauge and sliding-window histogram
below is a named, labeled registry metric (rendered by the Prometheus
exporter and shipped over the STATS frame), and the old attribute
surface (``metrics.served``, ``metrics.percentile_ms(99)``,
``metrics.worker_recent_s``...) is preserved as properties so existing
call sites and tests keep working unchanged.

The move also fixes a real race: the percentile deques used to be bare
``collections.deque``s appended by scoring workers while a monitoring
thread iterated them in ``snapshot`` — safe only because the serving
loop happened to take its backend lock around both. Registry
histograms own a per-metric lock and copy under it, so ``percentile_ms``
/ ``snapshot`` are safe from ANY thread (per-connection socket threads
and the scatter pool included), with or without the loop's lock.

Latencies are recorded per REQUEST (queue wait + service), batch stats
per micro-batch, so occupancy weighs each flush equally while the
percentiles weigh each query. The multi-host frontend additionally
records per-worker dispatch latencies, hedge fires/wins and failovers;
the tile counters carry prefetch accounting plus (new) per-shard
fault/eviction labels so a trace span can name WHICH shard faulted.
"""
from __future__ import annotations

import dataclasses
from collections import Counter as _Counter

import numpy as np

from ..obs.registry import MetricsRegistry


@dataclasses.dataclass
class MetricsSnapshot:
    served: int
    rejected: int
    dropped: int
    cache_hits: int
    batches: int
    p50_ms: float
    p99_ms: float
    mean_occupancy: float
    cache_hit_rate: float
    methods: dict[str, int]
    # out-of-core arena paging (0 / empty for dense single-shard indexes)
    page_faults: int = 0
    tile_hits: int = 0
    resident_tiles: int = 0
    tile_hit_rate: float = 0.0
    # double-buffered prefetch (0 when paging is demand-only)
    prefetched_tiles: int = 0
    prefetch_hits: int = 0
    prefetch_hit_rate: float = 0.0
    # serving-loop / network front-end gauges
    queue_depth: int = 0          # batcher backlog at the last sample
    max_queue_depth: int = 0      # backlog high-water mark
    connections: int = 0          # open client sessions
    total_connections: int = 0    # sessions ever accepted
    coalesce_rate: float = 0.0    # batched requests per kernel dispatch
    # multi-host dispatch (0 / empty for the single-host QueryServer)
    failed: int = 0          # requests unservable (shard lost all replicas)
    dispatches: int = 0
    hedges_fired: int = 0
    hedges_won: int = 0
    hedge_fire_rate: float = 0.0
    failovers: int = 0
    # real-RPC hedging (0 for in-process dispatch): duplicate requests
    # whose loser was cancelled, and dispatches that skipped a replica
    # already known dead (NOT failovers — no attempt was made)
    hedges_cancelled: int = 0
    skipped_dead: int = 0
    # replies undeliverable at session close/kick — counted, never silent
    dropped_replies: int = 0
    # networked data plane (0 when dispatch is in-process)
    channels_up: int = 0          # worker channels currently connected
    channel_reconnects: int = 0   # successful redials across the pool
    rpcs_sent: int = 0            # SHARD_QUERY frames sent
    rpcs_failed: int = 0          # dispatches failed by channel death
    worker_p99_ms: dict[str, float] = dataclasses.field(default_factory=dict)
    # per-shard tile-cache activity (empty when paging is off)
    shard_faults: dict[str, int] = dataclasses.field(default_factory=dict)
    shard_evictions: dict[str, int] = dataclasses.field(
        default_factory=dict)
    # tracing (0 when the tracer is off / absent)
    traces_finished: int = 0
    slow_queries: int = 0
    # compressed-arena serving (0 when no dict-coded shard was staged)
    arena_raw_bytes: int = 0      # bytes staged to device in raw form
    arena_comp_bytes: int = 0     # bytes staged in compressed (dict) form
    decodes: int = 0              # host-side shard decodes observed
    # pruned (branch-and-bound) scoring (0 when never dispatched)
    pruned_blocks: int = 0        # (query, block) cells killed by the bound
    prune_rate: float = 0.0       # killed / considered
    tiles_skipped: int = 0        # shard-tile visits never issued
    pruned_bytes_saved: int = 0   # arena bytes NOT read thanks to pruning
    # offline bulk lane (0 when no bulk job ever ran)
    bulk_jobs: int = 0            # jobs finished (any terminal status)
    bulk_queries: int = 0         # queries scored through the bulk lane
    bulk_shards_swept: int = 0    # shard sweeps completed
    bulk_yields: int = 0          # sweep suspensions to interactive work
    bulk_staged_bytes: int = 0    # arena bytes staged by bulk sweeps

    def report(self) -> str:
        meth = " ".join(f"{m}={n}" for m, n in sorted(self.methods.items()))
        s = (f"served={self.served} rejected={self.rejected} "
             f"dropped={self.dropped} batches={self.batches} "
             f"p50={self.p50_ms:.2f}ms p99={self.p99_ms:.2f}ms "
             f"occupancy={self.mean_occupancy:.2f} "
             f"cache_hit_rate={self.cache_hit_rate:.2f} "
             f"tiles[resident={self.resident_tiles} "
             f"faults={self.page_faults} "
             f"hit_rate={self.tile_hit_rate:.2f} "
             f"prefetch_hit_rate={self.prefetch_hit_rate:.2f}] "
             f"dispatch[{meth}]")
        if self.total_connections or self.max_queue_depth:
            s += (f" net[conns={self.connections}/"
                  f"{self.total_connections} "
                  f"queue_depth={self.queue_depth} "
                  f"max_depth={self.max_queue_depth} "
                  f"coalesce={self.coalesce_rate:.2f}]")
        if self.dispatches:
            workers = " ".join(f"{w}={p:.2f}ms"
                               for w, p in sorted(self.worker_p99_ms.items()))
            s += (f" shard_rpcs[n={self.dispatches} "
                  f"hedge_rate={self.hedge_fire_rate:.3f} "
                  f"hedges_won={self.hedges_won} "
                  f"hedges_cancelled={self.hedges_cancelled} "
                  f"failovers={self.failovers} "
                  f"skipped_dead={self.skipped_dead} "
                  f"failed={self.failed}] "
                  f"workers_p99[{workers}]")
        if self.rpcs_sent or self.channel_reconnects:
            s += (f" rpc[sent={self.rpcs_sent} "
                  f"failed={self.rpcs_failed} "
                  f"channels_up={self.channels_up} "
                  f"reconnects={self.channel_reconnects}]")
        if self.dropped_replies:
            s += f" dropped_replies={self.dropped_replies}"
        if self.traces_finished:
            s += (f" traces[done={self.traces_finished} "
                  f"slow={self.slow_queries}]")
        if self.arena_comp_bytes:
            s += (f" arena[raw={self.arena_raw_bytes}B "
                  f"comp={self.arena_comp_bytes}B "
                  f"decodes={self.decodes}]")
        if self.pruned_blocks or self.tiles_skipped:
            s += (f" prune[blocks={self.pruned_blocks} "
                  f"rate={self.prune_rate:.2f} "
                  f"tiles_skipped={self.tiles_skipped} "
                  f"bytes_saved={self.pruned_bytes_saved}B]")
        if self.bulk_jobs or self.bulk_queries:
            s += (f" bulk[jobs={self.bulk_jobs} "
                  f"queries={self.bulk_queries} "
                  f"shards={self.bulk_shards_swept} "
                  f"yields={self.bulk_yields} "
                  f"staged={self.bulk_staged_bytes}B]")
        return s


class ServingMetrics:
    """``window`` bounds the per-request/per-batch sample history (sliding
    window for the percentiles); the integer counters stay exact totals
    for the server's whole lifetime. All recorders and readers are
    thread-safe (each underlying registry metric owns its lock)."""

    def __init__(self, window: int = 65536,
                 registry: MetricsRegistry | None = None):
        self._window = window
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        r = self.registry
        h = lambda name, help: r.histogram(name, help, window=window)
        self._requests = r.counter(
            "serve_requests_total", "request outcomes",
            labels=("status",))
        self._served = self._requests.labels("ok")
        self._rejected = self._requests.labels("rejected")
        self._dropped = self._requests.labels("dropped")
        self._failed = self._requests.labels("failed")
        self._cache_hits = r.counter("serve_cache_hits_total",
                                     "result-cache hits")
        self._latency = h("serve_latency_seconds",
                          "end-to-end request latency (wait + service)")
        self._wait = h("serve_wait_seconds", "batcher queue wait")
        self._service = h("serve_service_seconds", "scoring service time")
        self._occupancy = h("serve_batch_occupancy",
                            "micro-batch fill fraction at flush")
        self._batch_size = h("serve_batch_size",
                             "requests per scored micro-batch")
        self._batches = r.counter("serve_batches_total",
                                  "micro-batches scored")
        self._batched = r.counter(
            "serve_batched_requests_total",
            "requests served through a micro-batch")
        self._methods = r.counter(
            "serve_dispatch_requests_total",
            "requests per scoring method", labels=("method",))
        self._queue_depth = r.gauge("serve_queue_depth",
                                    "batcher backlog")
        self._connections = r.gauge("serve_connections",
                                    "open client sessions")
        self._conn_total = r.counter("serve_connections_total",
                                     "client sessions ever accepted")
        self._tiles = r.counter(
            "serve_tile_events_total", "device tile-cache activity",
            labels=("event",))
        self._tile_hits = self._tiles.labels("hit")
        self._tile_faults = self._tiles.labels("fault")
        self._tile_prefetched = self._tiles.labels("prefetch")
        self._tile_prefetch_hits = self._tiles.labels("prefetch_hit")
        self._resident = r.gauge("serve_resident_tiles",
                                 "device tiles resident after last pass")
        self._shard_tiles = r.counter(
            "serve_shard_tile_events_total",
            "per-shard tile-cache faults/evictions/hits",
            labels=("shard", "event"))
        self._dispatches = r.counter("serve_shard_dispatches_total",
                                     "shard RPCs issued")
        self._hedges_fired = r.counter("serve_hedges_fired_total",
                                       "backup shard RPCs issued")
        self._hedges_won = r.counter(
            "serve_hedges_won_total", "backups that beat the primary")
        self._failovers = r.counter(
            "serve_failovers_total",
            "dispatches served by a non-primary replica")
        self._hedges_cancelled = r.counter(
            "serve_hedges_cancelled_total",
            "duplicate shard RPCs cancelled after losing the race")
        self._skipped_dead = r.counter(
            "serve_skipped_dead_total",
            "replicas skipped up front because already known dead")
        self._dropped_replies = r.counter(
            "serve_dropped_replies_total",
            "replies undeliverable at session close or kick")
        # networked data plane: per-node channel state + RPC outcomes
        # (repro.serve.rpc feeds these; all zero for in-process dispatch)
        self._channel_up = r.gauge(
            "serve_channel_up", "worker channel connected (1) or down (0)",
            labels=("node",))
        self._channel_reconnects = r.counter(
            "serve_channel_reconnects_total",
            "successful worker-channel redials", labels=("node",))
        self._rpcs = r.counter(
            "serve_rpc_total", "worker RPCs by node and outcome",
            labels=("node", "outcome"))
        self._worker_lat = r.histogram(
            "serve_worker_latency_seconds",
            "per-worker shard dispatch latency", labels=("worker",),
            window=window, recent=128)
        # compressed-arena serving: bytes staged host->device per form
        # ("raw" = expanded tiles, "comp" = dict+refs pairs) and the
        # host-side shard decode times (MappedArena.decode_observer)
        self._arena_bytes = r.counter(
            "serve_arena_bytes_total",
            "arena bytes staged to device, by tile form",
            labels=("form",))
        self._arena_raw = self._arena_bytes.labels("raw")
        self._arena_comp = self._arena_bytes.labels("comp")
        self._decode = h("serve_decode_seconds",
                         "host-side compressed shard decode time")
        self._decodes = r.counter("serve_decodes_total",
                                  "host-side compressed shard decodes")
        # pruned (branch-and-bound) scoring: block kills, skipped tile
        # visits, and the arena bytes those skips never read — the
        # threshold's leverage, visible in STATS and Prometheus
        self._prune_blocks = r.counter(
            "serve_pruned_blocks_total", "pruned-scoring block outcomes",
            labels=("outcome",))
        self._pruned_blocks = self._prune_blocks.labels("pruned")
        self._prune_considered = self._prune_blocks.labels("considered")
        self._tiles_skipped = r.counter(
            "serve_pruned_tiles_skipped_total",
            "shard-tile visits skipped entirely by pruning")
        self._prune_bytes_saved = r.counter(
            "serve_pruned_bytes_saved_total",
            "arena bytes not read thanks to pruning")
        # offline bulk lane: shard-major sweeps that run when no
        # interactive batch is due — per-job outcomes, shard/query
        # throughput, preemption yields, and the staged-bytes headline
        self._bulk_jobs = r.counter(
            "serve_bulk_jobs_total", "bulk jobs by terminal status",
            labels=("status",))
        self._bulk_queries = r.counter(
            "serve_bulk_queries_total",
            "queries scored through the bulk lane")
        self._bulk_shards = r.counter(
            "serve_bulk_shards_total", "bulk shard sweeps completed")
        self._bulk_yields = r.counter(
            "serve_bulk_yields_total",
            "bulk sweep suspensions yielding to interactive work")
        self._bulk_staged = r.counter(
            "serve_bulk_staged_bytes_total",
            "arena bytes staged to device by bulk sweeps")
        self._bulk_shard_s = h("serve_bulk_shard_seconds",
                               "wall time per bulk shard sweep")
        # Optional back-reference set by the owning backend so snapshots
        # carry trace counts (finished / slow) without a separate poll.
        self.tracer = None

    # -- recording ---------------------------------------------------------
    def record_request(self, *, wait_s: float, service_s: float,
                       cached: bool = False) -> None:
        self._served.inc()
        self._wait.observe(wait_s)
        self._service.observe(service_s)
        self._latency.observe(wait_s + service_s)
        if cached:
            self._cache_hits.inc()

    def record_batch(self, size: int, occupancy: float, method: str) -> None:
        self._batch_size.observe(size)
        self._occupancy.observe(occupancy)
        self._methods.labels(method).inc(size)
        self._batches.inc()
        self._batched.inc(size)

    def set_queue_depth(self, depth: int) -> None:
        """Gauge: batcher backlog (sampled by the serving loop)."""
        self._queue_depth.set(depth)

    def record_connection(self, delta: int) -> None:
        """Gauge: a client session opened (+1) or closed (-1). Called
        from per-connection threads; the gauge locks internally."""
        self._connections.inc(delta)
        if delta > 0:
            self._conn_total.inc(delta)

    def record_rejected(self) -> None:
        self._rejected.inc()

    def record_dropped(self) -> None:
        self._dropped.inc()

    def record_failed(self) -> None:
        """A request that could not be served: some shard it needs has no
        live replica left."""
        self._failed.inc()

    def record_tiles(self, *, hits: int, faults: int, resident: int,
                     prefetched: int = 0, prefetch_hits: int = 0) -> None:
        """Device-tile cache activity for one scoring pass: cache hits,
        page faults (host->device shard stages, prefetches included), the
        resident-tile gauge after the pass, and the prefetch counters."""
        if hits:
            self._tile_hits.inc(hits)
        if faults:
            self._tile_faults.inc(faults)
        self._resident.set(resident)
        if prefetched:
            self._tile_prefetched.inc(prefetched)
        if prefetch_hits:
            self._tile_prefetch_hits.inc(prefetch_hits)

    def record_shard_tile(self, shard, event: str, n: int = 1) -> None:
        """Per-shard tile-cache event ("hit" / "fault" / "eviction"):
        the DeviceTileCache observer feeds this so traces and the
        exporter can name WHICH shard faulted."""
        self._shard_tiles.labels(shard, event).inc(n)

    def record_arena_bytes(self, *, raw: int = 0, comp: int = 0) -> None:
        """Bytes newly staged to device during one scoring pass, split by
        tile form (deltas of the tile cache's staged-byte counters)."""
        if raw:
            self._arena_raw.inc(raw)
        if comp:
            self._arena_comp.inc(comp)

    def record_decode(self, seconds: float) -> None:
        """One host-side compressed shard decode (storage observer)."""
        self._decodes.inc()
        self._decode.observe(seconds)

    def record_prune(self, *, blocks_total: int, blocks_pruned: int,
                     tiles_skipped: int, bytes_saved: int) -> None:
        """One pruned dispatch's accounting (a core.query.PruneStats
        delta): cells considered/killed by the bound, shard-tile visits
        never issued, and arena bytes never read."""
        if blocks_total:
            self._prune_considered.inc(blocks_total)
        if blocks_pruned:
            self._pruned_blocks.inc(blocks_pruned)
        if tiles_skipped:
            self._tiles_skipped.inc(tiles_skipped)
        if bytes_saved > 0:
            self._prune_bytes_saved.inc(bytes_saved)

    def record_bulk_shard(self, *, staged_bytes: int,
                          seconds: float) -> None:
        """One bulk shard sweep: bytes it staged (0 when the tile was
        already resident) and its wall time."""
        self._bulk_shards.inc()
        if staged_bytes:
            self._bulk_staged.inc(staged_bytes)
        self._bulk_shard_s.observe(seconds)

    def record_bulk_yield(self) -> None:
        """The bulk lane suspended a sweep for due interactive work."""
        self._bulk_yields.inc()

    def record_bulk_job(self, status: str, *, queries: int) -> None:
        """A bulk job reached a terminal status."""
        self._bulk_jobs.labels(status).inc()
        if queries and status == "done":
            self._bulk_queries.inc(queries)

    def record_worker(self, worker: str, latency_s: float) -> None:
        """One shard dispatch served by ``worker`` (hedged or not)."""
        self._dispatches.inc()
        self._worker_lat.labels(worker).observe(latency_s)

    def record_hedges(self, *, fired: int, won: int,
                      cancelled: int = 0) -> None:
        if fired:
            self._hedges_fired.inc(fired)
        if won:
            self._hedges_won.inc(won)
        if cancelled:
            self._hedges_cancelled.inc(cancelled)

    def record_failovers(self, n: int) -> None:
        if n:
            self._failovers.inc(n)

    def record_skipped_dead(self, n: int) -> None:
        """Replicas filtered before dispatch because already known dead
        — distinct from failovers, which are at-call-time failures."""
        if n:
            self._skipped_dead.inc(n)

    def record_reply_dropped(self, n: int = 1) -> None:
        """A reply that could not be delivered (outbox full at kick, or
        queued behind a dead socket at drain)."""
        if n:
            self._dropped_replies.inc(n)

    def record_channel(self, node: str, *, up: bool,
                       reconnect: bool = False) -> None:
        """Worker-channel state transition (the reconnecting pool)."""
        self._channel_up.labels(node).set(1 if up else 0)
        if reconnect:
            self._channel_reconnects.labels(node).inc()

    def record_rpc(self, node: str, outcome: str, n: int = 1) -> None:
        """One worker RPC outcome: "sent", "ok", "failed", "cancelled"."""
        if n:
            self._rpcs.labels(node, outcome).inc(n)

    # -- legacy attribute surface ------------------------------------------
    @property
    def served(self) -> int:
        return self._served.value

    @property
    def rejected(self) -> int:
        return self._rejected.value

    @property
    def dropped(self) -> int:
        return self._dropped.value

    @property
    def failed(self) -> int:
        return self._failed.value

    @property
    def cache_hits(self) -> int:
        return self._cache_hits.value

    @property
    def n_batches(self) -> int:
        return self._batches.value

    @property
    def batched_requests(self) -> int:
        return self._batched.value

    @property
    def method_counts(self) -> "_Counter[str]":
        return _Counter({vals[0]: child.value
                         for vals, child in self._methods.children()})

    @property
    def page_faults(self) -> int:
        return self._tile_faults.value

    @property
    def tile_hits(self) -> int:
        return self._tile_hits.value

    @property
    def resident_tiles(self) -> int:
        return int(self._resident.value)

    @property
    def prefetched_tiles(self) -> int:
        return self._tile_prefetched.value

    @property
    def prefetch_hits(self) -> int:
        return self._tile_prefetch_hits.value

    @property
    def arena_raw_bytes(self) -> int:
        return self._arena_raw.value

    @property
    def arena_comp_bytes(self) -> int:
        return self._arena_comp.value

    @property
    def decodes(self) -> int:
        return self._decodes.value

    @property
    def pruned_blocks(self) -> int:
        return self._pruned_blocks.value

    @property
    def prune_considered(self) -> int:
        return self._prune_considered.value

    @property
    def tiles_skipped(self) -> int:
        return self._tiles_skipped.value

    @property
    def pruned_bytes_saved(self) -> int:
        return self._prune_bytes_saved.value

    @property
    def bulk_jobs(self) -> int:
        return sum(child.value for _, child in self._bulk_jobs.children())

    @property
    def bulk_queries(self) -> int:
        return self._bulk_queries.value

    @property
    def bulk_shards_swept(self) -> int:
        return self._bulk_shards.value

    @property
    def bulk_yields(self) -> int:
        return self._bulk_yields.value

    @property
    def bulk_staged_bytes(self) -> int:
        return self._bulk_staged.value

    @property
    def queue_depth(self) -> int:
        return int(self._queue_depth.value)

    @property
    def max_queue_depth(self) -> int:
        return int(self._queue_depth.max)

    @property
    def connections(self) -> int:
        return int(self._connections.value)

    @property
    def total_connections(self) -> int:
        return self._conn_total.value

    @property
    def dispatches(self) -> int:
        return self._dispatches.value

    @property
    def hedges_fired(self) -> int:
        return self._hedges_fired.value

    @property
    def hedges_won(self) -> int:
        return self._hedges_won.value

    @property
    def failovers(self) -> int:
        return self._failovers.value

    @property
    def hedges_cancelled(self) -> int:
        return self._hedges_cancelled.value

    @property
    def skipped_dead(self) -> int:
        return self._skipped_dead.value

    @property
    def dropped_replies(self) -> int:
        return self._dropped_replies.value

    @property
    def channels_up(self) -> int:
        return sum(int(child.value)
                   for _, child in self._channel_up.children())

    @property
    def channel_reconnects(self) -> int:
        return sum(child.value
                   for _, child in self._channel_reconnects.children())

    def rpc_count(self, outcome: str) -> int:
        return sum(child.value for vals, child in self._rpcs.children()
                   if vals[1] == outcome)

    @property
    def worker_recent_s(self) -> dict[str, np.ndarray]:
        """Recent-window dispatch latencies per worker (consistent
        copies — adaptive hedging derives its p95 from these)."""
        return {vals[0]: child.recent_values()
                for vals, child in self._worker_lat.children()}

    def shard_tile_counts(self, event: str) -> dict[str, int]:
        return {vals[0]: child.value
                for vals, child in self._shard_tiles.children()
                if vals[1] == event and child.value}

    # -- reading -----------------------------------------------------------
    def percentile_ms(self, p: float) -> float:
        return self._latency.percentile(p) * 1e3

    def snapshot(self) -> MetricsSnapshot:
        n_cacheable = self.served
        tile_hits, page_faults = self.tile_hits, self.page_faults
        n_tiles = tile_hits + page_faults
        prefetched, prefetch_hits = (self.prefetched_tiles,
                                     self.prefetch_hits)
        dispatches = self.dispatches
        hedges_fired = self.hedges_fired
        n_batches = self.n_batches
        p50, p99 = self._latency.percentiles((50, 99))
        return MetricsSnapshot(
            page_faults=page_faults,
            tile_hits=tile_hits,
            resident_tiles=self.resident_tiles,
            tile_hit_rate=(tile_hits / n_tiles if n_tiles else 0.0),
            prefetched_tiles=prefetched,
            prefetch_hits=prefetch_hits,
            prefetch_hit_rate=(prefetch_hits / prefetched
                               if prefetched else 0.0),
            queue_depth=self.queue_depth,
            max_queue_depth=self.max_queue_depth,
            connections=self.connections,
            total_connections=self.total_connections,
            coalesce_rate=(self.batched_requests / n_batches
                           if n_batches else 0.0),
            failed=self.failed,
            dispatches=dispatches,
            hedges_fired=hedges_fired,
            hedges_won=self.hedges_won,
            hedge_fire_rate=(hedges_fired / dispatches
                             if dispatches else 0.0),
            failovers=self.failovers,
            hedges_cancelled=self.hedges_cancelled,
            skipped_dead=self.skipped_dead,
            dropped_replies=self.dropped_replies,
            channels_up=self.channels_up,
            channel_reconnects=self.channel_reconnects,
            rpcs_sent=self.rpc_count("sent"),
            rpcs_failed=self.rpc_count("failed"),
            worker_p99_ms={
                vals[0]: child.percentile(99) * 1e3
                for vals, child in self._worker_lat.children()
                if len(child)},
            shard_faults=self.shard_tile_counts("fault"),
            shard_evictions=self.shard_tile_counts("eviction"),
            traces_finished=(self.tracer.finished_count
                             if self.tracer is not None else 0),
            slow_queries=(self.tracer.slow_count
                          if self.tracer is not None else 0),
            arena_raw_bytes=self.arena_raw_bytes,
            arena_comp_bytes=self.arena_comp_bytes,
            decodes=self.decodes,
            pruned_blocks=self.pruned_blocks,
            prune_rate=(self.pruned_blocks / self.prune_considered
                        if self.prune_considered else 0.0),
            tiles_skipped=self.tiles_skipped,
            pruned_bytes_saved=self.pruned_bytes_saved,
            bulk_jobs=self.bulk_jobs,
            bulk_queries=self.bulk_queries,
            bulk_shards_swept=self.bulk_shards_swept,
            bulk_yields=self.bulk_yields,
            bulk_staged_bytes=self.bulk_staged_bytes,
            served=n_cacheable,
            rejected=self.rejected,
            dropped=self.dropped,
            cache_hits=self.cache_hits,
            batches=n_batches,
            p50_ms=p50 * 1e3,
            p99_ms=p99 * 1e3,
            mean_occupancy=self._occupancy.mean(),
            cache_hit_rate=(self.cache_hits / n_cacheable
                            if n_cacheable else 0.0),
            methods=dict(self.method_counts),
        )
