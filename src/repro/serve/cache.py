"""LRU caches for the serving hot paths.

Two cacheable artifacts dominate repeated traffic:

* whole-query results — identical (terms, threshold) pairs recur under
  real workloads (health probes, popular sequences); a hit skips queue,
  kernel, and selection entirely.
* single-term row gathers — COBS point queries (ell = 1, the paper's
  Table 3 single-k-mer workload) reduce to one ANDed arena row; hot terms
  are answered from a host-side row cache without touching the device.

Both are plain LRU with hit/miss counters exposed to the metrics module.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Any, Hashable, Optional

import numpy as np


class LRUCache:
    """Bounded mapping with least-recently-used eviction and hit stats."""

    def __init__(self, capacity: int):
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = capacity
        self._d: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key: Hashable) -> Optional[Any]:
        if self.capacity == 0:
            self.misses += 1
            return None
        try:
            v = self._d[key]
        except KeyError:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return v

    def put(self, key: Hashable, value: Any) -> None:
        if self.capacity == 0:
            return
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


def result_key(terms: np.ndarray, threshold: float, top_k: int = 0) -> tuple:
    """Cache key for a whole query: digest of the distinct packed terms
    plus the selection inputs (coverage threshold, or top-k when > 0)."""
    digest = hashlib.blake2b(np.ascontiguousarray(terms).tobytes(),
                             digest_size=16).digest()
    return (digest, terms.shape[0], float(threshold), int(top_k))


def term_key(term: np.ndarray) -> int:
    """Cache key for one packed term: its 64-bit value."""
    return int(term[0]) | (int(term[1]) << 32)
