"""Frontend: the scatter/gather half of the sharded serving data plane.

Life of a request (compare QueryServer, the single-host engine):

1. ``submit`` compiles the pattern, answers empty queries immediately, and
   otherwise lands the request in the same shape-bucketed micro-batcher.
2. ``step`` polls the batcher; each due micro-batch is SCATTERED shard by
   shard: for every v2 manifest shard, the ``ShardPlacement`` names the
   replica ranking and the ``HedgedExecutor`` dispatches the batch to the
   preferred live ``ShardWorker`` — firing a backup request at the next
   replica if the primary dawdles past the hedge deadline ('The Tail at
   Scale'), and failing over entirely when a worker is down. While shard
   i scores, shard i+1's owner prefetches its tile (double buffering
   across hosts).
3. Workers return per-query CANDIDATES (doc, score pairs already cut to
   the coverage threshold or local top-k); the frontend GATHERS them and
   runs the final selection under the engine's exact total order
   (descending score, ties ascending doc id) — the same score-combine as
   ``index/distributed.py``'s distributed top-k, so results are
   bit-identical to the single-host QueryEngine.

Clocking: with ``latency_models`` (node -> ShardSim) every dispatch
latency is simulated on the executor's injected SimClock and the frontend
reads request timestamps off that same clock — tests and benchmarks are
fully deterministic, straggler/hedge behavior included. Without models,
dispatch is timed on the wall clock (production mode).
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional

import numpy as np

from ..core.query import (SearchResult, compile_pattern, coverage_cutoff)
from ..index.hedge import (AllReplicasFailed, AttemptFailed, HedgedExecutor,
                           ShardSim)
from ..index.placement import ShardPlacement
from ..obs import EventLog, KernelProfiler, Tracer
from .base import ServingBackend
from .batcher import MicroBatch, MicroBatcher
from .metrics import ServingMetrics
from .request import QueryRequest, QueryResponse, Status
from .worker import ShardWorker


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    term_pad: int = 64          # bucket granularity (multiples of this)
    max_batch: int = 32         # micro-batch cap per bucket
    max_wait_s: float = 0.002   # flush timer for partially-filled buckets
    max_queued: int = 1024      # backpressure cap across all buckets
    # Fit bucket boundaries to the observed term-length histogram
    # (MicroBatcher adaptive mode; mirrors ServerConfig).
    adaptive_buckets: bool = False
    default_threshold: float = 0.8
    default_top_k: int = 10     # k for top_k() convenience calls
    hedge_after_s: float = 0.05  # backup-request deadline per shard dispatch
    max_hedges: int = 1
    # Adaptive hedging (ROADMAP open item): derive hedge_after from the
    # OBSERVED per-worker latency histogram instead of the fixed config
    # value. After every scored batch the frontend takes each worker's
    # dispatch-latency p95 (workers with >= hedge_auto_min_samples
    # samples) and sets the executor's hedge deadline to the MEDIAN of
    # those p95s: with one straggler among >= 3 workers the median tracks
    # a *healthy* worker's p95, so backups fire exactly against dispatches
    # that exceed what the fleet normally achieves. hedge_after_s is the
    # initial value until enough samples accumulate.
    hedge_auto: bool = False
    hedge_auto_min_samples: int = 16
    hedge_auto_floor_s: float = 1e-5   # sanity floor (never hedge-at-zero)
    # Concurrent scatter: per-shard dispatches are issued through a thread
    # pool of this size so worker compute overlaps across hosts (<= 1 =
    # sequential). Only active in wall-clock mode — simulated-latency runs
    # share one deterministic event clock and stay sequential regardless.
    scatter_threads: int = 4
    # Threshold-driven pruned scoring on every worker: shard dispatches
    # whose coverage threshold predicts enough block pruning run through
    # the chunked early-exit executor (see ShardWorker._score_pruned) —
    # gathered results stay bit-identical either way. Setting this
    # overrides the flags the workers were constructed with.
    pruned: bool = False
    prune_chunk: int = 32
    # -- observability (mirrors ServerConfig; see repro.obs) --
    tracing: bool = True
    trace_slow_ms: float = 0.0
    trace_ring: int = 256
    trace_log: Optional[str] = None
    profile_kernels: bool = True


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class Frontend(ServingBackend):
    def __init__(self, workers: dict[str, ShardWorker],
                 placement: ShardPlacement,
                 config: FrontendConfig = FrontendConfig(), *,
                 clock: Optional[Callable[[], float]] = None,
                 latency_models: Optional[dict[str, ShardSim]] = None):
        # a node holding zero shards (more hosts than shard replicas) needs
        # no worker; every replicating node must hold its full replica set
        for node, held in placement.replica_assignment().items():
            if not held:
                continue
            if node not in workers:
                raise ValueError(f"placement node {node} replicates shards "
                                 f"{held} but has no worker")
            gaps = [g for g in held if not workers[node].holds(g)]
            if gaps:
                raise ValueError(
                    f"worker {node} missing replica shards {gaps}")
        self.workers = workers
        self.placement = placement
        self.config = config
        self.executor = HedgedExecutor(
            shards=dict(latency_models) if latency_models else {},
            hedge_after=config.hedge_after_s, max_hedges=config.max_hedges)
        self._simulated = bool(latency_models)
        if clock is None:
            clock = ((lambda: self.executor.clock.now) if self._simulated
                     else time.monotonic)
        self.clock = clock
        self.batcher = MicroBatcher(
            term_pad=config.term_pad, max_batch=config.max_batch,
            max_wait_s=config.max_wait_s, max_queued=config.max_queued,
            adaptive=config.adaptive_buckets)
        self.metrics = ServingMetrics()
        # Observability plane (mirrors QueryServer): tracer + slow-query
        # event log + kernel profiler shared by every worker, all feeding
        # the one metrics registry.
        self.events = EventLog(config.trace_log, ring=max(64,
                                                          config.trace_ring))
        self.tracer = Tracer(enabled=config.tracing, ring=config.trace_ring,
                             slow_ms=config.trace_slow_ms, sink=self.events,
                             clock=self.clock)
        self.metrics.tracer = self.tracer
        self.profiler = KernelProfiler(self.metrics.registry, None,
                                       enabled=config.profile_kernels)
        for w in workers.values():
            w.profiler = self.profiler
            w.tiles.observer = self._tile_observer(w)
            if config.pruned:
                w.pruned = True
                w.prune_chunk = int(config.prune_chunk)
        self._responses: dict[int, QueryResponse] = {}
        self._next_id = 0
        self._dispatch_seq = 0
        first = next(iter(workers.values()))
        self.params = first.params
        self.n_docs = first.layout.n_docs
        # Concurrent scatter pool (wall-clock mode only: simulated runs
        # share one deterministic event clock, so their dispatches stay
        # sequential and bit-reproducible).
        self._pool: Optional[ThreadPoolExecutor] = None
        if not self._simulated and config.scatter_threads > 1:
            self._pool = ThreadPoolExecutor(
                max_workers=config.scatter_threads,
                thread_name_prefix="scatter")

    # -- control plane -------------------------------------------------------
    def fail_worker(self, node: str) -> list[int]:
        """Mark a host down (placement failover + dead dispatch). Returns
        the shards whose primary moved to a replica."""
        moved = self.placement.fail(node)
        if node in self.workers:
            self.workers[node].fail()
        if node in self.executor.shards:
            self.executor.shards[node].failed = True
        return moved

    def recover_worker(self, node: str) -> list[int]:
        restored = self.placement.recover(node)
        if node in self.workers:
            self.workers[node].recover()
        if node in self.executor.shards:
            self.executor.shards[node].failed = False
        return restored

    def _tile_observer(self, w: ShardWorker):
        """DeviceTileCache observer for one worker: caches index tiles by
        LOCAL shard slot, so translate back to the GLOBAL shard id before
        the per-shard fault/eviction counters see it. Workers may fault
        from scatter-pool threads — the counters lock internally."""
        def on_event(local: int, event: str, seconds: float) -> None:
            g = (int(w.shard_ids[local])
                 if 0 <= local < len(w.shard_ids) else int(local))
            self.metrics.record_shard_tile(g, event)
        return on_event

    # -- submission ----------------------------------------------------------
    def submit(self, pattern=None, *, terms: Optional[np.ndarray] = None,
               threshold: Optional[float] = None,
               top_k: Optional[int] = None,
               deadline: Optional[float] = None,
               trace_id: int = 0) -> int:
        """Accept one query; ``top_k`` switches the request from coverage-
        threshold selection to exact global top-k. A nonzero ``trace_id``
        (e.g. minted by a remote client and carried over the wire) is
        honored; otherwise the tracer mints one."""
        if (pattern is None) == (terms is None):
            raise ValueError("pass exactly one of pattern / terms")
        if terms is None:
            terms = compile_pattern(pattern, self.params)
        threshold = (self.config.default_threshold if threshold is None
                     else threshold)
        now = self.clock()
        rid = self._next_id
        self._next_id += 1
        trace = self.tracer.begin(rid, trace_id=trace_id or None,
                                  started_s=now)
        if terms.shape[0] == 0:
            empty = SearchResult(np.zeros(0, np.int32),
                                 np.zeros(0, np.int32), 0, 0)
            self.metrics.record_request(wait_s=0.0, service_s=0.0)
            resp = QueryResponse(rid, Status.OK, empty)
            if trace is not None:
                trace.add("fast_path", now, self.clock(), {"path": "empty"})
            self._responses[rid] = self.finalize_trace(trace, resp)
            return rid
        req = QueryRequest(rid, terms, terms.shape[0], threshold,
                           submitted_at=now, deadline=deadline,
                           top_k=int(top_k) if top_k else 0, trace=trace)
        if not self.batcher.submit(req):
            self.metrics.record_rejected()
            resp = QueryResponse(rid, Status.REJECTED)
            if trace is not None:
                trace.add("reject", now, self.clock(),
                          {"reason": "backpressure"})
            self._responses[rid] = self.finalize_trace(trace, resp)
        return rid

    # -- scatter/gather ------------------------------------------------------
    def _staged(self, cache: dict, worker: ShardWorker, buf, n_valid):
        key = worker.device
        hit = cache.get(key)
        if hit is None:
            hit = cache[key] = worker.stage_batch(buf, n_valid)
        return hit

    def _scatter_sequential(self, staged, buf, n_valid, cutoffs, topks,
                            Q: int):
        """Shard-by-shard hedged dispatch on one (possibly simulated)
        clock: every shard scatters at the same event instant, the slowest
        completion bounds the batch. Returns ([(node, latency, result)]
        in shard order, max completion latency)."""
        ex = self.executor
        t_base = ex.clock.now
        max_done = 0.0
        out = []
        n_shards = self.placement.n_shards
        for g in range(n_shards):
            if g + 1 < n_shards:
                # double buffering across hosts: stage shard g+1's tile
                # on its owner while shard g scores (wherever it lands)
                try:
                    nxt = self.placement.owner(g + 1)
                    self.workers[nxt].prefetch_shard(g + 1)
                except RuntimeError:
                    pass

            def call(node, g=g):
                w = self.workers[node]
                terms_dev, nvalid_dev = self._staged(staged, w, buf,
                                                     n_valid)
                return w.score_candidates(g, terms_dev, nvalid_dev,
                                          cutoffs, topks, Q)

            self._dispatch_seq += 1
            # rewind the event clock to the batch start per shard, track
            # the slowest completion
            ex.clock.now = t_base
            node, lat, res = ex.run(
                self._dispatch_seq, self.placement.replicas(g), call)
            max_done = max(max_done, lat)
            out.append((node, lat, res))
        ex.clock.now = t_base + max_done
        return out, max_done

    def _scatter_concurrent(self, staged, buf, n_valid, cutoffs, topks,
                            Q: int):
        """Concurrent scatter: every shard's dispatch runs on the thread
        pool so worker compute overlaps ACROSS hosts (each worker still
        serializes its own dispatches — one device per host).

        Wall-clock mode only. Semantics match sequential wall-clock
        dispatch exactly: hedging stays off (a synchronous in-process
        backup can never win — see index/hedge.py), failover walks the
        replica ranking inline, and the executor's failover/completion
        stats are aggregated in the submitting thread so the executor is
        never shared across threads. Gather order stays deterministic:
        futures are consumed in shard order, and the final per-query sort
        under (-score, doc) is order-independent anyway."""
        ex = self.executor
        n_shards = self.placement.n_shards
        replica_sets = [self.placement.replicas(g) for g in range(n_shards)]
        # stage the batch once per device up front: worker staging caches
        # are plain dicts (not thread-safe) and staging is cheap
        for replicas in replica_sets:
            for node in replicas:
                self._staged(staged, self.workers[node], buf, n_valid)
        # prefetch every shard tile on its owner before the dispatch wave:
        # transfers are issued asynchronously, so by the time a pool
        # thread's kernel asks for the tile it is (being) staged — the
        # all-at-once analogue of the sequential path's double buffering
        for g in range(n_shards):
            try:
                self.workers[self.placement.owner(g)].prefetch_shard(g)
            except RuntimeError:
                pass

        def dispatch(g: int):
            for rank, node in enumerate(replica_sets[g]):
                w = self.workers[node]
                terms_dev, nvalid_dev = staged[w.device]
                t0 = time.perf_counter()
                try:
                    res = w.score_candidates(g, terms_dev, nvalid_dev,
                                             cutoffs, topks, Q)
                except AttemptFailed:
                    continue
                return node, time.perf_counter() - t0, res, rank
            raise AllReplicasFailed(f"shard {g}: all replicas failed")

        futures = [self._pool.submit(dispatch, g) for g in range(n_shards)]
        out, failed = [], None
        for fut in futures:
            try:
                node, lat, res, rank = fut.result()
            except AllReplicasFailed as e:
                failed = e          # keep draining so the pool is clean
                continue
            self._dispatch_seq += 1
            ex.failovers += rank
            ex.completions.append((self._dispatch_seq, node, lat, False))
            out.append((node, lat, res))
        if failed is not None:
            raise failed
        return out

    def _scatter(self, staged, buf, n_valid, cutoffs, topks, Q: int):
        """Dispatch hook: scatter one staged batch across every shard and
        return ([(node, latency, (cands, method))] in shard order,
        max completion latency). Subclasses with a different transport
        (repro.serve.rpc.RpcFrontend) override just this seam."""
        if self._pool is not None and self.placement.n_shards > 1:
            results = self._scatter_concurrent(staged, buf, n_valid,
                                               cutoffs, topks, Q)
            max_done = max((lat for _, lat, _ in results), default=0.0)
            return results, max_done
        return self._scatter_sequential(staged, buf, n_valid, cutoffs,
                                        topks, Q)

    def score_batch(self, batch: MicroBatch) -> None:
        """Scatter/score/gather one flushed micro-batch. Public so an
        active serving loop (repro.serve.loop) can pull batches off
        ``poll_batches`` and score them from worker threads."""
        t0 = self.clock()
        Q, B = batch.size, batch.bucket
        q_pad = _next_pow2(Q)
        buf = np.zeros((q_pad, B, 2), dtype=np.uint32)
        n_valid = np.zeros(q_pad, dtype=np.int32)
        cutoffs = np.zeros(q_pad, dtype=np.int32)
        topks = np.zeros(q_pad, dtype=np.int32)
        for i, r in enumerate(batch.requests):
            buf[i, : r.n_terms] = r.terms
            n_valid[i] = r.n_terms
            k = r.top_k
            topks[i] = k
            if not k:
                cutoffs[i] = coverage_cutoff(r.threshold, r.n_terms)

        staged: dict = {}
        gathered: list[list[tuple[np.ndarray, np.ndarray]]] = \
            [[] for _ in range(Q)]
        ex = self.executor
        fired0, won0, fo0 = ex.hedges_fired, ex.hedges_won, ex.failovers
        canc0, skip0 = ex.hedges_cancelled, ex.skipped_dead
        tiles0 = self._tile_counters()
        prune0 = self._prune_counters()
        traced = any(r.trace is not None for r in batch.requests)
        method = ""
        t_sc0 = self.clock()
        try:
            results, max_done = self._scatter(staged, buf, n_valid,
                                              cutoffs, topks, Q)
        except AllReplicasFailed:
            # a shard lost every replica mid-flight: the batch is already
            # out of the batcher, so answer every request FAILED instead of
            # raising it into the serving loop and losing the rids
            # (only this failure domain — kernel/device errors propagate)
            t_fail = self.clock()
            for r in batch.requests:
                self.metrics.record_failed()
                resp = QueryResponse(
                    r.request_id, Status.FAILED,
                    wait_s=max(0.0, t0 - r.submitted_at))
                if r.trace is not None:
                    r.trace.add("queue_wait", r.submitted_at, t0,
                                {"flush": batch.reason or "direct",
                                 "batch_size": Q})
                    r.trace.add("scatter", t_sc0, t_fail,
                                {"outcome": "all_replicas_failed"})
                self._responses[r.request_id] = self.finalize_trace(
                    r.trace, resp)
            return
        # gather in shard order — deterministic however dispatch ran
        for node, lat, (cands, method) in results:
            self.metrics.record_worker(node, lat)
            for i in range(Q):
                gathered[i].append(cands[i])
        service = max_done if self._simulated else self.clock() - t0

        self.metrics.record_hedges(fired=ex.hedges_fired - fired0,
                                   won=ex.hedges_won - won0,
                                   cancelled=ex.hedges_cancelled - canc0)
        self.metrics.record_failovers(ex.failovers - fo0)
        self.metrics.record_skipped_dead(ex.skipped_dead - skip0)
        if self.config.hedge_auto:
            self._adapt_hedge_after()
        self.metrics.record_batch(Q, self.batcher.occupancy(batch), method)
        th, tf, tp, tph = self._tile_counters()
        self.metrics.record_tiles(
            hits=th - tiles0[0], faults=tf - tiles0[1],
            resident=sum(len(w.tiles) for w in self.workers.values()),
            prefetched=tp - tiles0[2], prefetch_hits=tph - tiles0[3])
        # pruned-dispatch deltas across the fleet (workers accumulate
        # PruneStats per dispatch; this batch's share is the difference)
        pr = self._prune_counters()
        if pr[0] != prune0[0] or pr[2] != prune0[2]:
            self.metrics.record_prune(
                blocks_total=pr[0] - prune0[0],
                blocks_pruned=pr[1] - prune0[1],
                tiles_skipped=pr[2] - prune0[2],
                bytes_saved=max(0, (pr[4] - prune0[4])
                                - (pr[3] - prune0[3])))

        # Batch-level shard_dispatch marks, replayed into every member
        # request's trace: one span per shard naming the serving node and
        # its role — "primary" (the placement's preferred replica),
        # "backup" (a hedged backup request won the race), or "failover"
        # (the primary was found dead at dispatch time). The executor
        # appends exactly one completion per dispatch in shard order, so
        # the tail of ex.completions lines up with ``results``.
        marks: list[tuple[str, float, float, dict]] = []
        if traced:
            comps = list(ex.completions)[-len(results):]
            for g, (node, lat, _res) in enumerate(results):
                hedged = bool(comps[g][3]) if g < len(comps) else False
                replicas = self.placement.replicas(g)
                role = ("primary" if replicas and node == replicas[0]
                        else ("backup" if hedged else "failover"))
                marks.append(("shard_dispatch", t_sc0, t_sc0 + lat,
                              {"shard": g, "node": node, "role": role,
                               "hedged": int(hedged)}))

        for i, r in enumerate(batch.requests):
            ts0 = self.clock()
            result = self._gather(gathered[i], r, int(topks[i]),
                                  int(cutoffs[i]))
            wait = max(0.0, t0 - r.submitted_at)
            self.metrics.record_request(wait_s=wait, service_s=service)
            resp = QueryResponse(
                r.request_id, Status.OK, result, method=method,
                batch_size=Q, wait_s=wait, service_s=service)
            if r.trace is not None:
                r.trace.add("queue_wait", r.submitted_at, t0,
                            {"flush": batch.reason or "direct",
                             "batch_size": Q})
                for name, s, e, tags in marks:
                    r.trace.add(name, s, e, tags)
                r.trace.add("gather", ts0, self.clock())
            self._responses[r.request_id] = self.finalize_trace(
                r.trace, resp)

    def _adapt_hedge_after(self) -> None:
        """hedge_after from the observed per-worker latency histograms:
        the median across workers of each worker's dispatch-latency p95
        (see FrontendConfig.hedge_auto). Median, not pooled p95 — with a
        straggler holding 1/n of the dispatches, the POOLED p95 rises to
        the straggler's latency and hedging would never fire; the
        cross-worker median keeps tracking the healthy fleet. Runs after
        every batch, so the p95 is taken over the RECENT sample window
        (metrics.worker_recent_s), not the full percentile history."""
        per_worker = [
            float(np.percentile(q, 95))
            for q in self.metrics.worker_recent_s.values()
            if q.size >= self.config.hedge_auto_min_samples]
        if not per_worker:
            return
        self.executor.hedge_after = max(self.config.hedge_auto_floor_s,
                                        float(np.median(per_worker)))

    @property
    def hedge_after_s(self) -> float:
        """The hedge deadline currently in force (adapted when
        ``hedge_auto`` is on, else the configured value)."""
        return self.executor.hedge_after

    def _tile_counters(self) -> tuple[int, int, int, int]:
        ws = self.workers.values()
        return (sum(w.tiles.hits for w in ws),
                sum(w.tiles.faults for w in ws),
                sum(w.tiles.prefetched for w in ws),
                sum(w.tiles.prefetch_hits for w in ws))

    def _prune_counters(self) -> tuple[int, int, int, int, int]:
        """(blocks_total, blocks_pruned, visits_skipped, bytes_read,
        baseline_bytes) summed over the fleet's cumulative PruneStats."""
        ws = self.workers.values()
        return (sum(w.prune_stats.blocks_total for w in ws),
                sum(w.prune_stats.blocks_pruned for w in ws),
                sum(w.prune_stats.shard_visits_skipped for w in ws),
                sum(w.prune_stats.bytes_read for w in ws),
                sum(w.prune_baseline_bytes for w in ws))

    def _gather(self, parts: list[tuple[np.ndarray, np.ndarray]],
                req: QueryRequest, top_k: int, cutoff: int) -> SearchResult:
        """Final selection over gathered candidates — the distributed
        score-combine. Blocks partition documents, so each doc appears in
        exactly one shard's candidates and the global sort under
        (-score, doc id) reproduces the single-host engine exactly."""
        docs = np.concatenate([p[0] for p in parts]) if parts else \
            np.zeros(0, np.int64)
        scores = np.concatenate([p[1] for p in parts]) if parts else \
            np.zeros(0, np.int32)
        order = np.lexsort((docs, -scores))
        if top_k:
            order = order[: min(top_k, self.n_docs)]
            cut = int(scores[order[-1]]) if order.size else 0
        else:
            cut = cutoff
        return SearchResult(docs[order].astype(np.int32),
                            scores[order].astype(np.int32),
                            req.n_terms, cut)

    # -- serving loop (poll_batches / step / drain / take_response /
    # retract / pop_responses come from ServingBackend) ----------------------
    def reset_metrics(self, *, clear_caches: bool = False) -> None:
        """Fresh counters (drivers call this after jit warmup). The
        frontend holds no result caches — ``clear_caches`` is accepted for
        driver compatibility with QueryServer and ignored."""
        self.metrics = ServingMetrics()
        self.metrics.tracer = self.tracer
        self.profiler.bind_registry(self.metrics.registry)
        self.executor.completions.clear()
        self.executor.hedges_fired = 0
        self.executor.hedges_won = 0
        self.executor.hedges_cancelled = 0
        self.executor.failovers = 0
        self.executor.skipped_dead = 0
