"""Shape-bucketed dynamic micro-batching.

Queries arrive with arbitrary term counts; jit'd scoring is shape-
specialized. Padding every query to the global maximum wastes compute,
while padding each to its own length explodes the jit cache. The batcher
takes the middle road the serving literature (and COBS §3's bulk queries)
points at: queries are grouped into *buckets* by padded term length
(multiples of ``term_pad``), and each bucket accumulates a dense
micro-batch that flushes when it is full, when its oldest entry has waited
``max_wait_s``, or on an explicit drain. Bucket count — and therefore the
jit-cache footprint — is bounded by the term-length spread, not the query
count.

Backpressure is a hard cap on queued requests: ``submit`` refuses beyond
``max_queued`` and the caller answers the client with Status.REJECTED
instead of letting the queue grow without bound. Deadline handling is at
flush time: expired requests are returned separately and never scored.

The batcher is passive (no threads): a driver calls ``submit`` and then
``poll``/``drain`` from its own loop, which keeps it deterministic for
tests and embeddable under any async runtime.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque

from ..core.query import padded_len
from .request import QueryRequest


@dataclasses.dataclass
class MicroBatch:
    """A dense, same-bucket group of live requests ready to score."""
    bucket: int                       # padded term length of every member
    requests: list[QueryRequest]

    @property
    def size(self) -> int:
        return len(self.requests)


class MicroBatcher:
    def __init__(self, *, term_pad: int = 64, max_batch: int = 32,
                 max_wait_s: float = 0.002, max_queued: int = 1024):
        if max_batch < 1 or max_queued < 1:
            raise ValueError("max_batch and max_queued must be >= 1")
        self.term_pad = term_pad
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_queued = max_queued
        # bucket -> FIFO of requests; OrderedDict gives deterministic
        # bucket visit order (insertion order of first use).
        self._buckets: "OrderedDict[int, deque[QueryRequest]]" = OrderedDict()
        self._queued = 0

    # -- enqueue -----------------------------------------------------------
    def __len__(self) -> int:
        return self._queued

    @property
    def full(self) -> bool:
        return self._queued >= self.max_queued

    def bucket_of(self, n_terms: int) -> int:
        return padded_len(n_terms, self.term_pad)

    def submit(self, req: QueryRequest) -> bool:
        """Queue a request; False = refused (backpressure)."""
        if self.full:
            return False
        b = self.bucket_of(req.n_terms)
        req.bucket = b
        self._buckets.setdefault(b, deque()).append(req)
        self._queued += 1
        return True

    # -- flush -------------------------------------------------------------
    def _take(self, q: "deque[QueryRequest]", now: float, limit: int,
              expired: list[QueryRequest]) -> list[QueryRequest]:
        live: list[QueryRequest] = []
        while q and len(live) < limit:
            r = q.popleft()
            self._queued -= 1
            (expired if r.expired(now) else live).append(r)
        return live

    def poll(self, now: float, *, force: bool = False
             ) -> tuple[list[MicroBatch], list[QueryRequest]]:
        """Collect every bucket that is due at ``now``.

        Returns (batches, expired): dense micro-batches to score plus the
        requests whose deadline passed while queued (to answer DROPPED).
        force=True flushes everything regardless of fill/wait — the drain
        path and the load-generator's end-of-run.
        """
        batches: list[MicroBatch] = []
        expired: list[QueryRequest] = []
        for b, q in list(self._buckets.items()):
            while q:
                due = (force or len(q) >= self.max_batch
                       or now - q[0].submitted_at >= self.max_wait_s
                       or q[0].expired(now))
                if not due:
                    break
                live = self._take(q, now, self.max_batch, expired)
                if live:
                    batches.append(MicroBatch(b, live))
            if not q:
                del self._buckets[b]
        return batches, expired

    def occupancy(self, batch: MicroBatch) -> float:
        return batch.size / self.max_batch
