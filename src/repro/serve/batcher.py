"""Shape-bucketed dynamic micro-batching.

Queries arrive with arbitrary term counts; jit'd scoring is shape-
specialized. Padding every query to the global maximum wastes compute,
while padding each to its own length explodes the jit cache. The batcher
takes the middle road the serving literature (and COBS §3's bulk queries)
points at: queries are grouped into *buckets* by padded term length
(multiples of ``term_pad``), and each bucket accumulates a dense
micro-batch that flushes when it is full, when its oldest entry has waited
``max_wait_s``, or on an explicit drain. Bucket count — and therefore the
jit-cache footprint — is bounded by the term-length spread, not the query
count.

Backpressure is a hard cap on queued requests: ``submit`` refuses beyond
``max_queued`` and the caller answers the client with Status.REJECTED
instead of letting the queue grow without bound. Deadline handling is at
poll time: every poll sweeps EXPIRED requests out of their buckets —
wherever they sit in the queue, not just at the head — returns them
separately, and never scores them; ``next_due_at`` accounts for every
queued deadline so an active dispatcher wakes in time to answer the
drop.

The batcher is passive (no threads): a driver calls ``submit`` and then
``poll``/``drain`` from its own loop, which keeps it deterministic for
tests and embeddable under any async runtime. ``repro.serve.loop`` wraps
it in exactly such a runtime — an active dispatcher thread that sleeps
until ``next_due_at`` and wakes on submission — so network clients get
fill/wait-timer flushes without any caller poking the server.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque

from ..core.query import padded_len
from .request import QueryRequest


@dataclasses.dataclass
class MicroBatch:
    """A dense, same-bucket group of live requests ready to score."""
    bucket: int                       # padded term length of every member
    requests: list[QueryRequest]
    # why and when the batch flushed ("full" / "timer" / "force") — trace
    # spans tag the flush reason so a p99 investigation can tell
    # wait-timer flushes from fill flushes at a glance
    reason: str = ""
    flushed_at: float = 0.0

    @property
    def size(self) -> int:
        return len(self.requests)


class MicroBatcher:
    def __init__(self, *, term_pad: int = 64, max_batch: int = 32,
                 max_wait_s: float = 0.002, max_queued: int = 1024):
        if max_batch < 1 or max_queued < 1:
            raise ValueError("max_batch and max_queued must be >= 1")
        self.term_pad = term_pad
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_queued = max_queued
        # bucket -> FIFO of requests; OrderedDict gives deterministic
        # bucket visit order (insertion order of first use).
        self._buckets: "OrderedDict[int, deque[QueryRequest]]" = OrderedDict()
        self._queued = 0

    # -- enqueue -----------------------------------------------------------
    def __len__(self) -> int:
        return self._queued

    @property
    def full(self) -> bool:
        return self._queued >= self.max_queued

    def bucket_of(self, n_terms: int) -> int:
        return padded_len(n_terms, self.term_pad)

    def submit(self, req: QueryRequest) -> bool:
        """Queue a request; False = refused (backpressure)."""
        if self.full:
            return False
        b = self.bucket_of(req.n_terms)
        req.bucket = b
        self._buckets.setdefault(b, deque()).append(req)
        self._queued += 1
        return True

    def retract_last(self, rid: int) -> QueryRequest | None:
        """Remove and return a JUST-submitted request (still the tail of
        its bucket) — the serving loop's outstanding-work cap uses this
        to bounce an enqueue it only recognizes as over-budget after the
        backend's fast paths have had their chance. None = not found."""
        for b, q in self._buckets.items():
            if q and q[-1].request_id == rid:
                req = q.pop()
                self._queued -= 1
                if not q:
                    del self._buckets[b]
                return req
        return None

    def next_due_at(self) -> float | None:
        """Earliest server-clock instant at which some queued request
        becomes due: immediately for a full bucket, else the oldest
        entry's wait-timer expiry or ANY queued member's deadline,
        whichever is first. None = nothing queued. The active dispatcher
        (repro.serve.loop) sleeps until this instant instead of polling
        on a fixed tick — deadlines of non-head requests count, so their
        DROPPED replies are never delayed behind a long wait timer."""
        due = None
        for q in self._buckets.values():
            if not q:
                continue
            head = q[0]
            t = (head.submitted_at if len(q) >= self.max_batch
                 else head.submitted_at + self.max_wait_s)
            for r in q:
                if r.deadline is not None:
                    t = min(t, r.deadline)
            due = t if due is None else min(due, t)
        return due

    # -- flush -------------------------------------------------------------
    def _take(self, q: "deque[QueryRequest]", now: float, limit: int,
              expired: list[QueryRequest]) -> list[QueryRequest]:
        live: list[QueryRequest] = []
        while q and len(live) < limit:
            r = q.popleft()
            self._queued -= 1
            (expired if r.expired(now) else live).append(r)
        return live

    def poll(self, now: float, *, force: bool = False
             ) -> tuple[list[MicroBatch], list[QueryRequest]]:
        """Collect every bucket that is due at ``now``.

        Returns (batches, expired): dense micro-batches to score plus the
        requests whose deadline passed while queued (to answer DROPPED).
        force=True flushes everything regardless of fill/wait — the drain
        path and the load-generator's end-of-run.
        """
        batches: list[MicroBatch] = []
        expired: list[QueryRequest] = []
        for b, q in list(self._buckets.items()):
            if any(r.expired(now) for r in q):
                # deadline sweep: expired members ANYWHERE in the bucket
                # answer DROPPED now — the live ones keep waiting for
                # fill/timer rather than flushing early on their account
                keep: "deque[QueryRequest]" = deque()
                for r in q:
                    (keep if not r.expired(now) else expired).append(r)
                self._queued -= len(q) - len(keep)
                self._buckets[b] = q = keep
            while q:
                if len(q) >= self.max_batch:
                    reason = "full"
                elif now - q[0].submitted_at >= self.max_wait_s:
                    reason = "timer"
                elif force:
                    reason = "force"
                else:
                    break
                live = self._take(q, now, self.max_batch, expired)
                if live:
                    batches.append(MicroBatch(b, live, reason=reason,
                                              flushed_at=now))
            if not q:
                del self._buckets[b]
        return batches, expired

    def occupancy(self, batch: MicroBatch) -> float:
        return batch.size / self.max_batch
