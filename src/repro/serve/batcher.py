"""Shape-bucketed dynamic micro-batching.

Queries arrive with arbitrary term counts; jit'd scoring is shape-
specialized. Padding every query to the global maximum wastes compute,
while padding each to its own length explodes the jit cache. The batcher
takes the middle road the serving literature (and COBS §3's bulk queries)
points at: queries are grouped into *buckets* by padded term length
(multiples of ``term_pad``), and each bucket accumulates a dense
micro-batch that flushes when it is full, when its oldest entry has waited
``max_wait_s``, or on an explicit drain. Bucket count — and therefore the
jit-cache footprint — is bounded by the term-length spread, not the query
count. With ``adaptive=True`` the bucket boundaries are refit to the
observed term-length histogram (``fit_bucket_edges``), so workloads whose
lengths cluster between grid lines batch densely instead of padding up to
the next ``term_pad`` multiple.

Backpressure is a hard cap on queued requests: ``submit`` refuses beyond
``max_queued`` and the caller answers the client with Status.REJECTED
instead of letting the queue grow without bound. Deadline handling is at
poll time: every poll sweeps EXPIRED requests out of their buckets —
wherever they sit in the queue, not just at the head — returns them
separately, and never scores them; ``next_due_at`` accounts for every
queued deadline so an active dispatcher wakes in time to answer the
drop.

The batcher is passive (no threads): a driver calls ``submit`` and then
``poll``/``drain`` from its own loop, which keeps it deterministic for
tests and embeddable under any async runtime. ``repro.serve.loop`` wraps
it in exactly such a runtime — an active dispatcher thread that sleeps
until ``next_due_at`` and wakes on submission — so network clients get
fill/wait-timer flushes without any caller poking the server.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque

from ..core.query import padded_len
from .request import QueryRequest


def fit_bucket_edges(lengths, *, max_buckets: int = 8, quantum: int = 8
                     ) -> list[int]:
    """Bucket edges fitted to an observed term-length histogram.

    Plain ``padded_len(n, term_pad)`` buckets waste up to ``term_pad - 1``
    padded terms per query when the workload's lengths cluster between
    multiples. This picks up to ``max_buckets`` edges at the quantiles of
    the observed distribution, each rounded up to a multiple of
    ``quantum`` (the sublane granularity the kernels want) — so dense
    clusters get an edge of their own and the jit cache stays bounded by
    ``max_buckets`` shapes. Edges are sorted ascending and always cover
    the observed maximum; an empty sample returns []."""
    ls = sorted(int(x) for x in lengths if int(x) > 0)
    if not ls:
        return []
    edges: list[int] = []
    n = len(ls)
    for i in range(1, max_buckets + 1):
        idx = max(0, min(n - 1, (i * n) // max_buckets - 1))
        e = padded_len(ls[idx], quantum)
        if not edges or e > edges[-1]:
            edges.append(e)
    return edges


@dataclasses.dataclass
class MicroBatch:
    """A dense, same-bucket group of live requests ready to score."""
    bucket: int                       # padded term length of every member
    requests: list[QueryRequest]
    # why and when the batch flushed ("full" / "timer" / "force") — trace
    # spans tag the flush reason so a p99 investigation can tell
    # wait-timer flushes from fill flushes at a glance
    reason: str = ""
    flushed_at: float = 0.0

    @property
    def size(self) -> int:
        return len(self.requests)


class MicroBatcher:
    def __init__(self, *, term_pad: int = 64, max_batch: int = 32,
                 max_wait_s: float = 0.002, max_queued: int = 1024,
                 adaptive: bool = False, adapt_quantum: int = 8,
                 adapt_buckets: int = 8, adapt_every: int = 256,
                 adapt_window: int = 4096):
        if max_batch < 1 or max_queued < 1:
            raise ValueError("max_batch and max_queued must be >= 1")
        self.term_pad = term_pad
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_queued = max_queued
        # bucket -> FIFO of requests; OrderedDict gives deterministic
        # bucket visit order (insertion order of first use).
        self._buckets: "OrderedDict[int, deque[QueryRequest]]" = OrderedDict()
        self._queued = 0
        # Adaptive bucket boundaries: instead of the fixed term_pad grid,
        # fit edges to the observed term-length histogram every
        # ``adapt_every`` submissions (``fit_bucket_edges``), so a
        # workload clustered between grid lines batches densely. The
        # fitted edges only steer NEW submissions — queued requests keep
        # the bucket stamped at submit, so every in-flight micro-batch
        # stays shape-consistent. Queries past the largest fitted edge
        # fall back to the fixed grid (the edges always cover the
        # observed maximum, so this only happens on a fresh record).
        self.adaptive = bool(adaptive)
        self.adapt_quantum = int(adapt_quantum)
        self.adapt_buckets = int(adapt_buckets)
        self.adapt_every = max(1, int(adapt_every))
        self._observed: "deque[int]" = deque(maxlen=int(adapt_window))
        self._edges: list[int] = []
        self._since_fit = 0

    # -- enqueue -----------------------------------------------------------
    def __len__(self) -> int:
        return self._queued

    @property
    def full(self) -> bool:
        return self._queued >= self.max_queued

    @property
    def bucket_edges(self) -> list[int]:
        """The fitted edges currently steering new submissions ([] =
        fixed ``term_pad`` grid)."""
        return list(self._edges)

    def bucket_of(self, n_terms: int) -> int:
        for e in self._edges:
            if n_terms <= e:
                return e
        return padded_len(n_terms, self.term_pad)

    def fit(self, lengths=None) -> list[int]:
        """Refit bucket edges now — from ``lengths`` (a known workload
        histogram, e.g. a bulk job's term counts) or from the lengths
        observed so far. Returns the new edges."""
        sample = self._observed if lengths is None else lengths
        edges = fit_bucket_edges(sample, max_buckets=self.adapt_buckets,
                                 quantum=self.adapt_quantum)
        if edges:
            self._edges = edges
        self._since_fit = 0
        return list(self._edges)

    def observe(self, n_terms: int) -> None:
        """Record one observed term count (adaptive mode refits every
        ``adapt_every`` observations)."""
        self._observed.append(int(n_terms))
        self._since_fit += 1
        if self.adaptive and self._since_fit >= self.adapt_every:
            self.fit()

    def submit(self, req: QueryRequest) -> bool:
        """Queue a request; False = refused (backpressure)."""
        if self.full:
            return False
        if self.adaptive:
            self.observe(req.n_terms)
        b = self.bucket_of(req.n_terms)
        req.bucket = b
        self._buckets.setdefault(b, deque()).append(req)
        self._queued += 1
        return True

    def retract_last(self, rid: int) -> QueryRequest | None:
        """Remove and return a JUST-submitted request (still the tail of
        its bucket) — the serving loop's outstanding-work cap uses this
        to bounce an enqueue it only recognizes as over-budget after the
        backend's fast paths have had their chance. None = not found."""
        for b, q in self._buckets.items():
            if q and q[-1].request_id == rid:
                req = q.pop()
                self._queued -= 1
                if not q:
                    del self._buckets[b]
                return req
        return None

    def next_due_at(self) -> float | None:
        """Earliest server-clock instant at which some queued request
        becomes due: immediately for a full bucket, else the oldest
        entry's wait-timer expiry or ANY queued member's deadline,
        whichever is first. None = nothing queued. The active dispatcher
        (repro.serve.loop) sleeps until this instant instead of polling
        on a fixed tick — deadlines of non-head requests count, so their
        DROPPED replies are never delayed behind a long wait timer."""
        due = None
        for q in self._buckets.values():
            if not q:
                continue
            head = q[0]
            t = (head.submitted_at if len(q) >= self.max_batch
                 else head.submitted_at + self.max_wait_s)
            for r in q:
                if r.deadline is not None:
                    t = min(t, r.deadline)
            due = t if due is None else min(due, t)
        return due

    # -- flush -------------------------------------------------------------
    def _take(self, q: "deque[QueryRequest]", now: float, limit: int,
              expired: list[QueryRequest]) -> list[QueryRequest]:
        live: list[QueryRequest] = []
        while q and len(live) < limit:
            r = q.popleft()
            self._queued -= 1
            (expired if r.expired(now) else live).append(r)
        return live

    def poll(self, now: float, *, force: bool = False
             ) -> tuple[list[MicroBatch], list[QueryRequest]]:
        """Collect every bucket that is due at ``now``.

        Returns (batches, expired): dense micro-batches to score plus the
        requests whose deadline passed while queued (to answer DROPPED).
        force=True flushes everything regardless of fill/wait — the drain
        path and the load-generator's end-of-run.
        """
        batches: list[MicroBatch] = []
        expired: list[QueryRequest] = []
        for b, q in list(self._buckets.items()):
            if any(r.expired(now) for r in q):
                # deadline sweep: expired members ANYWHERE in the bucket
                # answer DROPPED now — the live ones keep waiting for
                # fill/timer rather than flushing early on their account
                keep: "deque[QueryRequest]" = deque()
                for r in q:
                    (keep if not r.expired(now) else expired).append(r)
                self._queued -= len(q) - len(keep)
                self._buckets[b] = q = keep
            while q:
                if len(q) >= self.max_batch:
                    reason = "full"
                elif now - q[0].submitted_at >= self.max_wait_s:
                    reason = "timer"
                elif force:
                    reason = "force"
                else:
                    break
                live = self._take(q, now, self.max_batch, expired)
                if live:
                    batches.append(MicroBatch(b, live, reason=reason,
                                              flushed_at=now))
            if not q:
                del self._buckets[b]
        return batches, expired

    def occupancy(self, batch: MicroBatch) -> float:
        return batch.size / self.max_batch
