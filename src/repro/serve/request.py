"""Request/response envelope for the query-serving subsystem.

A request is a *compiled* query: distinct packed terms plus the coverage
threshold. Pattern compilation (DNA string -> packed k-mers) happens once
at the server's front door (``QueryServer.submit``) so everything behind
the queue operates on fixed-shape term buffers.

Timestamps are seconds on the server's clock (``time.monotonic`` unless a
test injects its own); ``deadline`` is absolute on that clock.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

from ..core.query import SearchResult


class Status(str, enum.Enum):
    OK = "ok"                    # scored, result attached
    REJECTED = "rejected"        # backpressure: queue full at submit
    DROPPED = "dropped_deadline"  # deadline expired before scoring
    FAILED = "failed"            # unservable: a shard lost every replica


@dataclasses.dataclass
class QueryRequest:
    """One compiled query waiting to be scored."""

    request_id: int
    terms: np.ndarray            # uint32 [ell, 2] distinct packed terms
    n_terms: int                 # ell (terms.shape[0])
    threshold: float             # coverage fraction K
    submitted_at: float          # server-clock seconds
    deadline: Optional[float] = None   # absolute; None = never drop
    bucket: int = 0              # padded term length (set by the batcher)
    top_k: int = 0               # > 0 = exact top-k selection instead of
    #                              the coverage threshold
    # Observability: the Trace minted at admission (None = tracing off)
    # rides with the request so every layer it crosses can append spans.
    # ``trace`` holds live span state and is deliberately excluded from
    # equality/repr noise via compare=False.
    trace: Optional[object] = dataclasses.field(default=None, repr=False,
                                                compare=False)

    @property
    def trace_id(self) -> int:
        return self.trace.trace_id if self.trace is not None else 0

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline


@dataclasses.dataclass
class QueryResponse:
    """Outcome of one request.

    ``result`` is None unless ``status == Status.OK``. ``method`` names the
    kernel the planner dispatched ('' for cache hits and non-OK statuses);
    ``batch_size`` counts live queries in the micro-batch that served this
    request (1 for cache hits). ``wait_s``/``service_s`` split the latency
    into queueing and scoring time.
    """

    request_id: int
    status: Status
    result: Optional[SearchResult] = None
    method: str = ""
    batch_size: int = 0
    wait_s: float = 0.0
    service_s: float = 0.0
    cached: bool = False
    # Observability: the request's trace id (0 = untraced), the compact
    # per-stage timing breakdown {stage: seconds} the wire layer ships
    # back in the RESULT frame, and the full Trace for in-process
    # consumers (slow-query assertions, the loop's deliver span).
    trace_id: int = 0
    stages: Optional[dict] = None
    trace: Optional[object] = dataclasses.field(default=None, repr=False,
                                                compare=False)

    @property
    def latency_s(self) -> float:
        return self.wait_s + self.service_s
