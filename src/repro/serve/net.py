"""Network serving: a length-prefixed binary wire protocol over TCP.

This is the seam that turns the in-process serving library into a real
multi-user system: any number of client processes connect, pipeline
queries, and the ServingLoop coalesces them into shared micro-batches —
the cross-client batching the bit-sliced design's one-kernel-per-batch
economics depend on.

Framing is deliberately primitive (stdlib ``struct``, no schema
compiler): every frame is a 4-byte big-endian payload length followed by
the payload, whose first byte is the message type.

* ``HELLO``  (server -> client, once per connection): protocol version +
  the index parameters (n_hashes, kmer, canonical, fpr) and document
  count, so clients can compile DNA patterns to packed terms themselves —
  the wire carries compiled terms, never raw sequences.
* ``QUERY``  (client -> server): client-chosen request id (u64, echoed
  back — ids only need to be unique per connection), threshold (f64, NaN
  = server default), top_k (u32, 0 = threshold mode), deadline (f64
  RELATIVE seconds, <= 0 = none; the server rebases it onto its own
  clock, so client/server clock skew never drops a request), term count,
  then the packed uint32 little-endian term pairs.
* ``RESULT`` (server -> client): echoed request id, status byte
  (OK / REJECTED / DROPPED / FAILED — REJECTED is the 429-style
  backpressure reply, sent immediately when the queue cap refuses the
  request), the serving method + batch size, server-side wait/service
  seconds, and the SearchResult fields (n_terms, cutoff, doc ids,
  scores) as little-endian int32 arrays. A client reconstructs the exact
  SearchResult the in-process server produced — bit-identical, which the
  end-to-end property test asserts against a QueryEngine oracle.

Protocol version 2 (PR 6) adds end-to-end observability, all of it
OPTIONAL trailing bytes so version-1 frames remain valid:

* ``QUERY`` may carry a trailing u64 trace id (client-minted, nonzero):
  the server adopts it for the request's server-side trace, so a slow-
  query log line can be joined to the exact client call. A v1 client
  simply never appends it; the server treats absent as "no tracing".
* ``RESULT`` carries — only when the query carried a nonzero trace id —
  a trailing trace block: the echoed trace id plus a compact per-stage
  timing breakdown (stage name, total seconds) aggregated from the
  server-side trace spans (queue_wait / plan / kernel_score /
  shard_dispatch / gather ...).
* ``STATS`` (bidirectional): the client sends ``[MSG_STATS, format]``
  and the server replies with the same frame type carrying either a
  JSON metrics snapshot (format 0) or the Prometheus text exposition of
  the whole metrics registry (format 1).

Protocol version 3 (PR 9) adds the offline bulk lane:

* ``BULK`` (client -> server): a whole query set in one frame —
  client-chosen base request id (u64), threshold (f64, NaN = server
  default), top_k (u32, 0 = threshold mode), query count, then per
  query a u32 term count followed by the packed term pairs. The server
  submits the set to its attached ``BulkLane`` (shard-major sweep that
  runs in interactive idle time) and answers with ONE ``RESULT`` frame
  per query at ``rid_base + i`` when the sweep completes — the same
  RESULT format interactive queries use, so a client demultiplexes both
  lanes with one reader. A server without a bulk lane answers every
  query REJECTED immediately.

Protocol version 4 (PR 10) adds the worker data plane — the frames the
sharded frontend uses to scatter real RPCs at ShardWorker processes
(see repro.serve.rpc):

* ``SHARD_QUERY`` (frontend -> worker): one shard dispatch of one
  micro-batch — request id (u64), global shard id, padded query count,
  bucket width, live query count, then the per-query n_valid / cutoff /
  top-k arrays and the padded packed term buffer.
* ``SHARD_RESULT`` (worker -> frontend): echoed rid, status byte
  (OK / CANCELLED / FAILED), the scoring method (or the error text on
  FAILED), this dispatch's PruneStats delta, then per-query candidate
  (doc, score) arrays.
* ``CANCEL`` (frontend -> worker): echoed rid — fired when a hedged
  duplicate of the dispatch already won. The worker checks the rid's
  cancellation flag between shard tiles and answers CANCELLED without
  scoring the rest.
* ``PING``/``PONG``: liveness probe for the reconnecting channel pool.

A server pinned to ``proto_version=1`` (constructor knob) speaks the old
protocol bit-for-bit — the mixed-version interop tests hold both
directions: old client against a new server (pinned v1) and raw v1
frames against a v2 server.

Sessions are pipelined: a client may have any number of queries in
flight; responses come back in completion order (batch flushes), matched
by request id. Shutdown is graceful: ``NetServer.close(drain=True)``
stops accepting, lets the loop drain every queued request, writes every
response, then closes the sockets — clients see their answers, then EOF.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import math
import os
import queue
import socket
import struct
import threading
import time
from concurrent.futures import Future
from typing import Callable, Optional

import numpy as np

from ..core.index import IndexParams
from ..core.query import SearchResult, compile_pattern
from ..obs.export import render_prometheus
from .loop import LoopClosed, ServingLoop
from .request import QueryResponse, Status

PROTO_VERSION = 4        # v4: worker data plane (v3: BULK, v2: trace)
MIN_PROTO_VERSION = 1    # oldest version a client will still talk to

MSG_HELLO = 1
MSG_QUERY = 2
MSG_RESULT = 3
MSG_STATS = 4
MSG_BULK = 5
MSG_SHARD_QUERY = 6
MSG_SHARD_RESULT = 7
MSG_CANCEL = 8
MSG_PING = 9
MSG_PONG = 10

STATS_SNAPSHOT = 0       # JSON-encoded MetricsSnapshot
STATS_PROMETHEUS = 1     # Prometheus text exposition of the registry

_LEN = struct.Struct("!I")
# type, version, n_docs, n_hashes, kmer, canonical, fpr
_HELLO = struct.Struct("!BHIBBBd")
# type, rid, threshold, top_k, deadline_rel_s, n_terms
_QUERY = struct.Struct("!BQdIdI")
# type, rid, status, batch_size, wait_s, service_s, n_terms, cutoff,
# n_hits, method_len
_RESULT = struct.Struct("!BQBIddIiIB")
# type, rid_base, threshold, top_k, n_queries
_BULK = struct.Struct("!BQdII")
# per-query header inside a BULK frame: term count
_BULK_Q = struct.Struct("!I")
# optional QUERY tail: client-minted trace id
_TRACE_ID = struct.Struct("!Q")
# optional RESULT tail header: trace id, n_stages; each stage is a u8
# name length + name bytes + f64 total seconds
_TRACE_HEAD = struct.Struct("!QB")
_STAGE_SECONDS = struct.Struct("!d")

# v4 worker data plane
# type, rid, gshard, q_pad, bucket, n_live
_SHARD_QUERY = struct.Struct("!BQIIII")
# type, rid, status, method_len (method doubles as the error text on
# SHARD_FAILED), then the PruneStats delta and per-query candidates
_SHARD_RESULT = struct.Struct("!BQBB")
# blocks_total, blocks_pruned, shard_visits_skipped, bytes_read,
# baseline_bytes — this dispatch's pruning delta
_SHARD_PRUNE = struct.Struct("!5Q")
_SHARD_NQ = struct.Struct("!I")
# type, rid (CANCEL) / nonce (PING, PONG)
_RID_ONLY = struct.Struct("!BQ")

SHARD_OK = 0
SHARD_CANCELLED = 1
SHARD_FAILED = 2

# wire status byte <-> Status (order is the protocol, do not reorder)
_STATUS_CODES = (Status.OK, Status.REJECTED, Status.DROPPED, Status.FAILED)
_STATUS_TO_CODE = {s: i for i, s in enumerate(_STATUS_CODES)}

MAX_FRAME = 64 * 2**20          # sanity bound on a declared payload length


# -- framing helpers ---------------------------------------------------------

def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """n bytes or None on clean EOF at a frame boundary; raises
    ConnectionError on EOF mid-frame."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise ConnectionError("EOF mid-frame")
        buf += chunk
    return bytes(buf)


def read_frame(sock: socket.socket) -> Optional[bytes]:
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if n > MAX_FRAME:
        raise ConnectionError(f"frame length {n} exceeds {MAX_FRAME}")
    payload = _recv_exact(sock, n)
    if payload is None:
        raise ConnectionError("EOF before frame payload")
    return payload


def write_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(_LEN.pack(len(payload)) + payload)


# -- message encode/decode ----------------------------------------------------

def encode_hello(params: IndexParams, n_docs: int,
                 version: int = PROTO_VERSION) -> bytes:
    return _HELLO.pack(MSG_HELLO, version, n_docs, params.n_hashes,
                       params.kmer, int(params.canonical), params.fpr)


def decode_hello(payload: bytes) -> tuple[IndexParams, int, int]:
    (_, version, n_docs, n_hashes, kmer, canonical,
     fpr) = _HELLO.unpack(payload)
    return (IndexParams(n_hashes=n_hashes, fpr=fpr, kmer=kmer,
                        canonical=bool(canonical)), n_docs, version)


def encode_query(rid: int, terms: np.ndarray, threshold: Optional[float],
                 top_k: int, deadline_s: Optional[float],
                 trace_id: int = 0) -> bytes:
    """``trace_id`` nonzero appends the v2 trailing trace-id field — only
    send it to a server that announced protocol >= 2 (a v1 server's strict
    length check would tear the session)."""
    th = float("nan") if threshold is None else float(threshold)
    dl = 0.0 if deadline_s is None else float(deadline_s)
    body = np.ascontiguousarray(terms, dtype="<u4").tobytes()
    head = _QUERY.pack(MSG_QUERY, rid, th, int(top_k), dl,
                       terms.shape[0]) + body
    if trace_id:
        head += _TRACE_ID.pack(trace_id)
    return head


def decode_query(payload: bytes
                 ) -> tuple[int, np.ndarray, Optional[float], int,
                            Optional[float], int]:
    """Accepts BOTH v1 frames (terms only) and v2 frames (terms + the
    optional trailing trace id); returns trace_id 0 when absent."""
    (_, rid, th, top_k, dl, n_terms) = _QUERY.unpack_from(payload)
    body = payload[_QUERY.size:]
    trace_id = 0
    if len(body) == n_terms * 8 + _TRACE_ID.size:
        (trace_id,) = _TRACE_ID.unpack_from(body, n_terms * 8)
        body = body[: n_terms * 8]
    elif len(body) != n_terms * 8:
        raise ConnectionError(
            f"QUERY rid={rid}: {len(body)} term bytes != {n_terms} terms")
    terms = np.frombuffer(body, dtype="<u4").reshape(n_terms, 2)
    terms = terms.astype(np.uint32)          # native, writable
    return (rid, terms, None if math.isnan(th) else th, top_k,
            dl if dl > 0 else None, trace_id)


def _encode_trace_block(trace_id: int, stages: Optional[dict]) -> bytes:
    """Compact per-stage breakdown: trace id + up to 255 (name, seconds)
    pairs, insertion order preserved (admission -> delivery)."""
    items = list((stages or {}).items())[:255]
    out = [_TRACE_HEAD.pack(trace_id, len(items))]
    for name, seconds in items:
        nb = str(name).encode()[:255]
        out.append(struct.pack("!B", len(nb)) + nb
                   + _STAGE_SECONDS.pack(float(seconds)))
    return b"".join(out)


def _decode_trace_block(payload: bytes, off: int) -> tuple[int, dict]:
    (trace_id, n_stages) = _TRACE_HEAD.unpack_from(payload, off)
    off += _TRACE_HEAD.size
    stages: dict[str, float] = {}
    for _ in range(n_stages):
        nlen = payload[off]
        off += 1
        name = payload[off: off + nlen].decode()
        off += nlen
        (seconds,) = _STAGE_SECONDS.unpack_from(payload, off)
        off += _STAGE_SECONDS.size
        stages[name] = seconds
    return trace_id, stages


def encode_result(rid: int, resp: QueryResponse, *,
                  trace_id: int = 0) -> bytes:
    """``trace_id`` nonzero (the id the QUERY carried) appends the v2
    trace block with the response's per-stage breakdown."""
    res = resp.result
    method = resp.method.encode()[:255]
    if res is None:
        head = _RESULT.pack(MSG_RESULT, rid, _STATUS_TO_CODE[resp.status],
                            resp.batch_size, resp.wait_s, resp.service_s,
                            0, 0, 0, len(method))
        frame = head + method
    else:
        head = _RESULT.pack(MSG_RESULT, rid, _STATUS_TO_CODE[resp.status],
                            resp.batch_size, resp.wait_s, resp.service_s,
                            res.n_terms, int(res.threshold),
                            res.doc_ids.shape[0], len(method))
        frame = (head + method
                 + np.ascontiguousarray(res.doc_ids, dtype="<i4").tobytes()
                 + np.ascontiguousarray(res.scores, dtype="<i4").tobytes())
    if trace_id:
        frame += _encode_trace_block(trace_id, resp.stages)
    return frame


def decode_result(payload: bytes) -> tuple[int, "NetResult"]:
    (_, rid, code, batch_size, wait_s, service_s, n_terms, cutoff,
     n_hits, mlen) = _RESULT.unpack_from(payload)
    off = _RESULT.size
    method = payload[off: off + mlen].decode()
    off += mlen
    status = _STATUS_CODES[code]
    result = None
    if status == Status.OK:
        docs = np.frombuffer(payload, dtype="<i4", count=n_hits,
                             offset=off).astype(np.int32)
        scores = np.frombuffer(payload, dtype="<i4", count=n_hits,
                               offset=off + 4 * n_hits).astype(np.int32)
        result = SearchResult(docs, scores, n_terms, cutoff)
        off += 8 * n_hits
    trace_id, stages = 0, None
    if len(payload) > off:                   # v2 trailing trace block
        trace_id, stages = _decode_trace_block(payload, off)
    return rid, NetResult(status, result, method, batch_size, wait_s,
                          service_s, trace_id, stages)


def encode_stats(fmt: int, body: bytes = b"") -> bytes:
    """Both directions: the request is the bare [type, format] header,
    the reply appends the rendered body."""
    return struct.pack("!BB", MSG_STATS, fmt) + body


def decode_stats(payload: bytes) -> tuple[int, bytes]:
    if len(payload) < 2:
        raise ConnectionError("STATS frame too short")
    return payload[1], payload[2:]


def encode_bulk(rid_base: int, term_sets: list, threshold: Optional[float],
                top_k: int = 0) -> bytes:
    """One frame carrying a whole bulk query set; the server replies with
    one RESULT per query at ``rid_base + i``. Frames are bounded by
    MAX_FRAME — a client with more queries than fit splits into several
    BULK frames (each is an independent job)."""
    th = float("nan") if threshold is None else float(threshold)
    out = [_BULK.pack(MSG_BULK, rid_base, th, int(top_k), len(term_sets))]
    for t in term_sets:
        t = np.ascontiguousarray(t, dtype="<u4")
        out.append(_BULK_Q.pack(t.shape[0]) + t.tobytes())
    return b"".join(out)


def decode_bulk(payload: bytes
                ) -> tuple[int, list, Optional[float], int]:
    (_, rid_base, th, top_k, n_queries) = _BULK.unpack_from(payload)
    off = _BULK.size
    term_sets = []
    for i in range(n_queries):
        if off + _BULK_Q.size > len(payload):
            raise ConnectionError(f"BULK frame truncated at query {i}")
        (nt,) = _BULK_Q.unpack_from(payload, off)
        off += _BULK_Q.size
        nb = nt * 8
        if off + nb > len(payload):
            raise ConnectionError(f"BULK frame truncated at query {i}")
        terms = np.frombuffer(payload, dtype="<u4", count=nt * 2,
                              offset=off).reshape(nt, 2)
        term_sets.append(terms.astype(np.uint32))
        off += nb
    if off != len(payload):
        raise ConnectionError("BULK frame has trailing bytes")
    return rid_base, term_sets, None if math.isnan(th) else th, top_k


# -- v4 worker data plane ------------------------------------------------------

def encode_shard_query(rid: int, gshard: int, buf: np.ndarray,
                       n_valid: np.ndarray, cutoffs: np.ndarray,
                       topks: np.ndarray, n_live: int) -> bytes:
    """One shard dispatch of one micro-batch: the exact arrays
    Frontend.score_batch hands a local ShardWorker, so the remote path
    scores bit-identically to the in-process one."""
    q_pad, bucket, _ = buf.shape
    return b"".join((
        _SHARD_QUERY.pack(MSG_SHARD_QUERY, rid, gshard, q_pad, bucket,
                          int(n_live)),
        np.ascontiguousarray(n_valid, dtype="<i4").tobytes(),
        np.ascontiguousarray(cutoffs, dtype="<i4").tobytes(),
        np.ascontiguousarray(topks, dtype="<i4").tobytes(),
        np.ascontiguousarray(buf, dtype="<u4").tobytes(),
    ))


def decode_shard_query(payload: bytes
                       ) -> tuple[int, int, np.ndarray, np.ndarray,
                                  np.ndarray, np.ndarray, int]:
    (_, rid, gshard, q_pad, bucket, n_live) = _SHARD_QUERY.unpack_from(
        payload)
    off = _SHARD_QUERY.size
    want = off + 3 * 4 * q_pad + 8 * q_pad * bucket
    if len(payload) != want:
        raise ConnectionError(
            f"SHARD_QUERY rid={rid}: {len(payload)} bytes != {want}")

    def i32(n):
        nonlocal off
        a = np.frombuffer(payload, dtype="<i4", count=n, offset=off)
        off += 4 * n
        return a.astype(np.int32)

    n_valid, cutoffs, topks = i32(q_pad), i32(q_pad), i32(q_pad)
    buf = np.frombuffer(payload, dtype="<u4", count=q_pad * bucket * 2,
                        offset=off).reshape(q_pad, bucket, 2)
    return (rid, gshard, buf.astype(np.uint32), n_valid, cutoffs, topks,
            n_live)


def encode_shard_result(rid: int, status: int, method: str,
                        cands: Optional[list] = None,
                        prune: tuple = (0, 0, 0, 0, 0)) -> bytes:
    """status SHARD_OK carries per-query candidate (doc, score) arrays
    plus this dispatch's PruneStats delta; on SHARD_FAILED the method
    field carries the error text instead."""
    m = method.encode()[:255]
    out = [_SHARD_RESULT.pack(MSG_SHARD_RESULT, rid, status, len(m)), m,
           _SHARD_PRUNE.pack(*(int(x) for x in prune)),
           _SHARD_NQ.pack(len(cands or []))]
    for docs, scores in (cands or []):
        docs = np.ascontiguousarray(docs, dtype="<i4")
        out.append(_SHARD_NQ.pack(docs.shape[0]) + docs.tobytes()
                   + np.ascontiguousarray(scores, dtype="<i4").tobytes())
    return b"".join(out)


def decode_shard_result(payload: bytes
                        ) -> tuple[int, int, str, list, tuple]:
    (_, rid, status, mlen) = _SHARD_RESULT.unpack_from(payload)
    off = _SHARD_RESULT.size
    method = payload[off: off + mlen].decode()
    off += mlen
    prune = _SHARD_PRUNE.unpack_from(payload, off)
    off += _SHARD_PRUNE.size
    (n_queries,) = _SHARD_NQ.unpack_from(payload, off)
    off += _SHARD_NQ.size
    cands = []
    for i in range(n_queries):
        if off + _SHARD_NQ.size > len(payload):
            raise ConnectionError(f"SHARD_RESULT truncated at query {i}")
        (n,) = _SHARD_NQ.unpack_from(payload, off)
        off += _SHARD_NQ.size
        if off + 8 * n > len(payload):
            raise ConnectionError(f"SHARD_RESULT truncated at query {i}")
        docs = np.frombuffer(payload, dtype="<i4", count=n,
                             offset=off).astype(np.int32)
        scores = np.frombuffer(payload, dtype="<i4", count=n,
                               offset=off + 4 * n).astype(np.int32)
        cands.append((docs, scores))
        off += 8 * n
    if off != len(payload):
        raise ConnectionError("SHARD_RESULT frame has trailing bytes")
    return rid, status, method, cands, prune


def encode_cancel(rid: int) -> bytes:
    return _RID_ONLY.pack(MSG_CANCEL, rid)


def encode_ping(nonce: int, *, pong: bool = False) -> bytes:
    return _RID_ONLY.pack(MSG_PONG if pong else MSG_PING, nonce)


def decode_rid(payload: bytes) -> int:
    """rid of a CANCEL / nonce of a PING or PONG."""
    return _RID_ONLY.unpack_from(payload)[1]


# -- server -------------------------------------------------------------------

def _backend_info(backend) -> tuple[IndexParams, int]:
    """(index params, n_docs) of any serving backend."""
    index = getattr(backend, "index", None)
    if index is not None:
        return index.params, index.n_docs
    # Frontend / RpcFrontend expose params + n_docs directly (an
    # RpcFrontend has no local workers at all — they live behind RPC)
    params = getattr(backend, "params", None)
    if params is not None:
        return params, backend.n_docs
    worker = next(iter(backend.workers.values()))
    return worker.params, backend.n_docs


# Per-connection reply backlog (frames) before a client that stopped
# reading is kicked. Bounded so a stalled session can never hold memory
# or threads hostage.
OUTBOX_FRAMES = 1024


class _Session:
    """One accepted connection: the socket plus a bounded reply outbox
    drained by a dedicated writer thread. Loop threads enqueue replies
    and NEVER touch the socket — a client that stops reading fills its
    own outbox and gets kicked, instead of wedging a scoring worker in a
    blocking sendall and stalling every other client."""

    def __init__(self, sock: socket.socket,
                 on_drop: Optional[Callable[[int], None]] = None):
        self.sock = sock
        self.outbox: "queue.Queue[Optional[bytes]]" = queue.Queue(
            maxsize=OUTBOX_FRAMES)
        self.dropped_replies = 0
        self._on_drop = on_drop
        self.writer = threading.Thread(target=self._write_loop,
                                       name="serve-write", daemon=True)
        self.writer.start()

    def _drop(self, n: int = 1) -> None:
        """Account an undelivered reply — a drop is NEVER silent: it is
        counted here and surfaced through the server's metrics."""
        self.dropped_replies += n
        if self._on_drop is not None:
            try:
                self._on_drop(n)
            except Exception:
                pass

    def send(self, payload: bytes) -> None:
        try:
            self.outbox.put_nowait(payload)
        except queue.Full:
            self._drop()
            self.kick()                       # slow reader: drop the session

    def _write_loop(self) -> None:
        dead = False
        while True:
            p = self.outbox.get()
            if p is None:
                return
            if dead:
                self._drop()                  # drain, counting every loss
                continue
            try:
                write_frame(self.sock, p)
            except OSError:
                dead = True                   # client went away
                self._drop()

    def kick(self) -> None:
        """Force both directions down (unblocks reader AND writer)."""
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass

    def finish(self, timeout_s: float = 5.0) -> None:
        """Flush queued replies, stop the writer, close the socket.

        Drain-aware: wait (bounded by the deadline) for the writer to
        empty the outbox BEFORE enqueueing the shutdown sentinel — the
        old code put() the sentinel with a timeout, so a full outbox at
        close silently orphaned every queued reply. A peer that stalls
        past the deadline is kicked and the writer's counting drain
        accounts each undelivered frame in ``dropped_replies``."""
        deadline = time.monotonic() + timeout_s
        while not self.outbox.empty() and time.monotonic() < deadline:
            time.sleep(0.005)
        try:
            self.outbox.put(
                None, timeout=max(0.01, deadline - time.monotonic()))
        except queue.Full:
            # writer wedged on a stalled peer: sever the socket so the
            # write loop falls into its counting drain, then sentinel
            self.kick()
            try:
                self.outbox.put(None, timeout=timeout_s)
            except queue.Full:
                pass
        self.writer.join(timeout=timeout_s)
        self.kick()
        self.sock.close()


class NetServer:
    """TCP front door over a ServingLoop.

    One accept thread plus one reader thread per connection; responses
    are enqueued by the loop's completion callbacks into the session's
    bounded outbox and written by the session's writer thread, so a
    session is fully pipelined — the reader never waits for scoring, and
    the scorer never waits for any client's socket."""

    def __init__(self, loop: ServingLoop, *, host: str = "127.0.0.1",
                 port: int = 0, backlog: int = 128,
                 proto_version: int = PROTO_VERSION):
        if not MIN_PROTO_VERSION <= proto_version <= PROTO_VERSION:
            raise ValueError(f"proto_version {proto_version} unsupported")
        self.loop = loop
        # pinned to 1 the server speaks the old protocol bit-for-bit
        # (no trace fields, no STATS) — the interop escape hatch
        self.proto_version = proto_version
        self.params, self.n_docs = _backend_info(loop.backend)
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(backlog)
        self.address: tuple[str, int] = self._listener.getsockname()
        self._conns: set[_Session] = set()
        self._conns_lock = threading.Lock()
        self._accept_thread: Optional[threading.Thread] = None
        self._closing = False

    @property
    def metrics(self):
        return self.loop.backend.metrics

    def _record_drop(self, n: int) -> None:
        rec = getattr(self.metrics, "record_reply_dropped", None)
        if rec is not None:
            rec(n)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "NetServer":
        if not self.loop.running:
            self.loop.start()
        self._accept_thread = threading.Thread(
            target=self._accept, name="serve-accept", daemon=True)
        self._accept_thread.start()
        return self

    def close(self, *, drain: bool = True, stop_loop: bool = True) -> None:
        """Graceful shutdown: stop accepting, drain the loop (every
        queued request scored and its response enqueued), flush each
        session's outbox, then close the sockets — clients receive all
        their answers, then EOF."""
        self._closing = True
        try:
            self._listener.close()
        except OSError:
            pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
            self._accept_thread = None
        if stop_loop:
            self.loop.stop(drain=drain)
        with self._conns_lock:
            sessions, self._conns = list(self._conns), set()
        for s in sessions:
            s.finish()

    # -- connection handling -------------------------------------------------
    def _accept(self) -> None:
        while True:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return                        # listener closed
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conns_lock:
                if self._closing:
                    conn.close()
                    continue
                session = _Session(conn, on_drop=self._record_drop)
                self._conns.add(session)
            threading.Thread(target=self._serve_conn, args=(session,),
                             name="serve-conn", daemon=True).start()

    def _stats_body(self, fmt: int) -> bytes:
        if fmt == STATS_PROMETHEUS:
            return render_prometheus(self.metrics.registry).encode()
        snap = self.loop.metrics_snapshot()
        return json.dumps(dataclasses.asdict(snap)).encode()

    def _handle_bulk(self, session: _Session, payload: bytes) -> None:
        """BULK frame: hand the set to the attached bulk lane; the job's
        completion callback writes one RESULT per query at rid_base + i.
        No lane (or a lane refusing the job) answers REJECTED — the same
        429-style contract as interactive backpressure."""
        rid_base, term_sets, th, top_k = decode_bulk(payload)
        lane = getattr(self.loop, "bulk_lane", None)

        def reject_all() -> None:
            for i in range(len(term_sets)):
                session.send(encode_result(
                    rid_base + i, QueryResponse(-1, Status.REJECTED)))

        if lane is None:
            reject_all()
            return

        def on_done(job, rid_base=rid_base) -> None:
            if job.results is None:           # failed / cancelled sweep
                for i in range(job.n_queries):
                    session.send(encode_result(
                        rid_base + i,
                        QueryResponse(-1, Status.FAILED)))
                return
            wait_s = max(0.0, job.started_at - job.submitted_at)
            service_s = max(0.0, job.finished_at - job.started_at)
            for i, res in enumerate(job.results):
                session.send(encode_result(
                    rid_base + i,
                    QueryResponse(rid_base + i, Status.OK, result=res,
                                  method="bulk", batch_size=job.n_queries,
                                  wait_s=wait_s, service_s=service_s)))

        try:
            lane.submit(term_sets=term_sets, threshold=th, top_k=top_k,
                        tag=f"net:{rid_base}", on_done=on_done)
        except (ValueError, RuntimeError):
            reject_all()

    def _serve_conn(self, session: _Session) -> None:
        conn = session.sock
        self.metrics.record_connection(+1)
        v2 = self.proto_version >= 2
        v3 = self.proto_version >= 3
        owned = True                          # close() may take ownership
        try:
            session.send(encode_hello(self.params, self.n_docs,
                                      self.proto_version))
            while True:
                payload = read_frame(conn)
                if payload is None:
                    return                    # client closed its session
                if v2 and payload and payload[0] == MSG_STATS:
                    fmt, _ = decode_stats(payload)
                    session.send(encode_stats(fmt, self._stats_body(fmt)))
                    continue
                if v3 and payload and payload[0] == MSG_BULK:
                    self._handle_bulk(session, payload)
                    continue
                if not payload or payload[0] != MSG_QUERY:
                    raise ConnectionError(
                        f"unexpected message "
                        f"{payload[:1].hex() or 'empty'}")
                rid, terms, th, top_k, dl, tid = decode_query(payload)
                deadline = (None if dl is None
                            else self.loop.clock() + dl)
                # the trace block goes back only when the CLIENT asked
                # for tracing (nonzero trace id) on a v2 session
                tid = tid if v2 else 0

                def on_done(resp: QueryResponse, rid=rid,
                            tid=tid) -> None:
                    session.send(encode_result(rid, resp, trace_id=tid))

                try:
                    self.loop.submit(terms=terms, threshold=th,
                                     top_k=top_k or None,
                                     deadline=deadline, trace_id=tid,
                                     on_done=on_done)
                except LoopClosed:
                    # shutting down: 429-style refusal, session stays up
                    # until the client closes or the server finishes
                    session.send(encode_result(
                        rid, QueryResponse(-1, Status.REJECTED)))
        except (ConnectionError, OSError, struct.error):
            pass                      # torn/malformed session: drop it
        finally:
            self.metrics.record_connection(-1)
            with self._conns_lock:
                owned = session in self._conns
                self._conns.discard(session)
            if owned:
                # flush replies already enqueued (e.g. for requests still
                # in flight when the client half-closed), then close
                session.finish()


# -- client -------------------------------------------------------------------

@dataclasses.dataclass
class NetResult:
    """One wire response: status + the reconstructed SearchResult (None
    unless status == OK) plus the server-side timing split. On a traced
    v2 session ``trace_id`` echoes the id this client minted for the
    query and ``stages`` is the server-side per-stage breakdown (name ->
    total seconds) — joinable against the server's slow-query log."""
    status: Status
    result: Optional[SearchResult]
    method: str = ""
    batch_size: int = 0
    wait_s: float = 0.0
    service_s: float = 0.0
    trace_id: int = 0
    stages: Optional[dict] = None


# Client-minted trace ids: unique per process (counter) and salted with
# the pid so two client processes against one server rarely collide.
_TRACE_COUNTER = itertools.count(1)


def _mint_trace_id() -> int:
    return ((os.getpid() & 0xFFFF) << 40) | next(_TRACE_COUNTER)


class NetClient:
    """Pipelined client session.

    ``submit`` returns a Future resolved by the reader thread when the
    matching RESULT frame arrives; ``search``/``top_k`` are the blocking
    conveniences. Patterns compile client-side with the index parameters
    announced in the server's HELLO, so the wire only ever carries packed
    terms. Thread-safe: many threads may submit on one session."""

    def __init__(self, host: str, port: int, *, timeout_s: float = 30.0,
                 trace: bool = True):
        self.timeout_s = timeout_s
        self._sock = socket.create_connection((host, port),
                                              timeout=timeout_s)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        hello = read_frame(self._sock)
        if hello is None or hello[0] != MSG_HELLO:
            raise ConnectionError("no HELLO from server")
        self.params, self.n_docs, self.proto_version = decode_hello(hello)
        if not MIN_PROTO_VERSION <= self.proto_version <= PROTO_VERSION:
            raise ConnectionError(
                f"protocol version {self.proto_version} outside "
                f"[{MIN_PROTO_VERSION}, {PROTO_VERSION}]")
        # trace ids ride on queries only when the server can take them
        self.trace = bool(trace) and self.proto_version >= 2
        self._sock.settimeout(None)           # reader blocks until frames
        self._wlock = threading.Lock()
        self._flock = threading.Lock()
        self._futs: dict[int, Future] = {}
        self._stats_futs: "queue.SimpleQueue[Future]" = queue.SimpleQueue()
        self._next_rid = 0
        self._closed = False
        self._reader = threading.Thread(target=self._read_loop,
                                        name="netclient-read", daemon=True)
        self._reader.start()

    # -- submission ----------------------------------------------------------
    def submit(self, pattern=None, *, terms: Optional[np.ndarray] = None,
               threshold: Optional[float] = None,
               top_k: Optional[int] = None,
               deadline_s: Optional[float] = None) -> "Future[NetResult]":
        """Send one query; deadline_s is RELATIVE (server rebases it)."""
        if (pattern is None) == (terms is None):
            raise ValueError("pass exactly one of pattern / terms")
        if terms is None:
            terms = compile_pattern(pattern, self.params)
        fut: Future = Future()
        with self._flock:
            if self._closed:
                raise ConnectionError("client is closed")
            rid = self._next_rid
            self._next_rid += 1
            self._futs[rid] = fut
        tid = _mint_trace_id() if self.trace else 0
        payload = encode_query(rid, terms, threshold, int(top_k or 0),
                               deadline_s, trace_id=tid)
        try:
            with self._wlock:
                write_frame(self._sock, payload)
        except OSError as e:
            with self._flock:
                self._futs.pop(rid, None)
            raise ConnectionError(f"send failed: {e}") from e
        return fut

    def search(self, pattern=None, *, terms: Optional[np.ndarray] = None,
               threshold: Optional[float] = None,
               deadline_s: Optional[float] = None,
               timeout_s: Optional[float] = None) -> NetResult:
        return self.submit(pattern, terms=terms, threshold=threshold,
                           deadline_s=deadline_s).result(
                               timeout_s or self.timeout_s)

    def top_k(self, pattern=None, *, terms: Optional[np.ndarray] = None,
              k: int = 10, deadline_s: Optional[float] = None,
              timeout_s: Optional[float] = None) -> NetResult:
        return self.submit(pattern, terms=terms, top_k=k,
                           deadline_s=deadline_s).result(
                               timeout_s or self.timeout_s)

    # -- bulk lane ----------------------------------------------------------
    def submit_bulk(self, patterns=None, *, term_sets=None,
                    threshold: Optional[float] = None,
                    top_k: int = 0) -> "list[Future[NetResult]]":
        """Send a whole query set as one BULK frame (protocol >= 3); the
        server sweeps it through its offline bulk lane in interactive
        idle time. Returns one Future per query, in submission order —
        all resolve together when the sweep completes."""
        if self.proto_version < 3:
            raise ConnectionError("BULK requires protocol >= 3")
        if (patterns is None) == (term_sets is None):
            raise ValueError("pass exactly one of patterns / term_sets")
        if term_sets is None:
            term_sets = [compile_pattern(p, self.params) for p in patterns]
        futs: list[Future] = []
        with self._flock:
            if self._closed:
                raise ConnectionError("client is closed")
            rid_base = self._next_rid
            self._next_rid += len(term_sets)
            for i in range(len(term_sets)):
                fut: Future = Future()
                self._futs[rid_base + i] = fut
                futs.append(fut)
        payload = encode_bulk(rid_base, term_sets, threshold, top_k)
        try:
            with self._wlock:
                write_frame(self._sock, payload)
        except OSError as e:
            with self._flock:
                for i in range(len(term_sets)):
                    self._futs.pop(rid_base + i, None)
            raise ConnectionError(f"send failed: {e}") from e
        return futs

    def bulk(self, patterns=None, *, term_sets=None,
             threshold: Optional[float] = None, top_k: int = 0,
             timeout_s: Optional[float] = None) -> list[NetResult]:
        """Blocking bulk sweep: one result per query, submission order.
        Bulk jobs wait for interactive idle time, so pass a generous
        timeout for a loaded server."""
        futs = self.submit_bulk(patterns, term_sets=term_sets,
                                threshold=threshold, top_k=top_k)
        t = timeout_s or self.timeout_s
        return [f.result(t) for f in futs]

    # -- observability -------------------------------------------------------
    def stats(self, *, prometheus: bool = False,
              timeout_s: Optional[float] = None):
        """Server metrics over the wire (v2 sessions only): the parsed
        JSON MetricsSnapshot dict, or the raw Prometheus text exposition
        when ``prometheus=True``. STATS replies come back in request
        order on this session (the server answers them inline)."""
        if self.proto_version < 2:
            raise ConnectionError("STATS requires protocol >= 2")
        fut: Future = Future()
        with self._flock:
            if self._closed:
                raise ConnectionError("client is closed")
            self._stats_futs.put(fut)
        fmt = STATS_PROMETHEUS if prometheus else STATS_SNAPSHOT
        try:
            with self._wlock:
                write_frame(self._sock, encode_stats(fmt))
        except OSError as e:
            raise ConnectionError(f"send failed: {e}") from e
        body = fut.result(timeout_s or self.timeout_s)
        return body.decode() if prometheus else json.loads(body)

    # -- reader --------------------------------------------------------------
    def _read_loop(self) -> None:
        err: Optional[Exception] = None
        try:
            while True:
                payload = read_frame(self._sock)
                if payload is None:
                    break
                if payload and payload[0] == MSG_STATS:
                    _, body = decode_stats(payload)
                    try:
                        sfut = self._stats_futs.get_nowait()
                    except queue.Empty:
                        raise ConnectionError("unsolicited STATS reply")
                    sfut.set_result(body)
                    continue
                if not payload or payload[0] != MSG_RESULT:
                    raise ConnectionError(
                        f"unexpected message "
                        f"{payload[:1].hex() or 'empty'}")
                rid, res = decode_result(payload)
                with self._flock:
                    fut = self._futs.pop(rid, None)
                if fut is not None:
                    fut.set_result(res)
        except Exception as e:
            # broad on purpose: ANY reader death (torn socket, malformed
            # frame, decode error like an unknown status byte) must reach
            # the sweep below, or in-flight futures hang until their
            # callers' timeouts
            err = e
        with self._flock:
            # mark the session dead BEFORE sweeping, so a submit racing
            # this sweep either registers early enough to be swept here
            # or sees _closed and raises — never a forever-pending Future
            self._closed = True
            futs, self._futs = list(self._futs.values()), {}
        while True:
            try:
                futs.append(self._stats_futs.get_nowait())
            except queue.Empty:
                break
        for fut in futs:
            fut.set_exception(err or ConnectionError("session closed"))

    def close(self) -> None:
        with self._flock:
            self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_WR)   # polite half-close
        except OSError:
            pass
        self._reader.join(timeout=self.timeout_s)
        self._sock.close()

    def __enter__(self) -> "NetClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
