"""Serving subsystem.

Two serving surfaces live here:

* the COBS query-serving stack (the paper's workload): shape-bucketed
  micro-batching (`batcher`), kernel planning (`planner`), LRU caches
  (`cache`), latency/occupancy metrics (`metrics`), and the `QueryServer`
  front-end (`server`). Driven by `repro.launch.serve` and
  `benchmarks.serving`.
* LM inference steps (`step`) for the model substrate: prefill/decode and
  the greedy generation driver.
"""
from .batcher import MicroBatch, MicroBatcher
from .cache import LRUCache, result_key, term_key
from .metrics import MetricsSnapshot, ServingMetrics
from .planner import QueryPlan, QueryPlanner
from .request import QueryRequest, QueryResponse, Status
from .server import QueryServer, ServerConfig
from .step import make_prefill_step, make_decode_step, greedy_generate

__all__ = [
    "MicroBatch", "MicroBatcher", "LRUCache", "result_key", "term_key",
    "MetricsSnapshot", "ServingMetrics", "QueryPlan", "QueryPlanner",
    "QueryRequest", "QueryResponse", "Status", "QueryServer", "ServerConfig",
    "make_prefill_step", "make_decode_step", "greedy_generate",
]
