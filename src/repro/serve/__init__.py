"""Serving subsystem.

Two serving surfaces live here:

* the COBS query-serving stack (the paper's workload): shape-bucketed
  micro-batching (`batcher`), kernel planning (`planner`), LRU caches
  (`cache`), latency/occupancy metrics (`metrics`), and the `QueryServer`
  front-end (`server`). Driven by `repro.launch.serve` and
  `benchmarks.serving`.
* the multi-host sharded data plane: per-host `ShardWorker`s over
  placement-assigned v2 manifest shards (`worker`) and the scatter/gather
  `Frontend` with hedged dispatch and replica failover (`frontend`).
* the network front-end: `ServingLoop` (`loop`) wraps either backend in
  an active dispatcher + scoring workers, and `NetServer`/`NetClient`
  (`net`) speak the length-prefixed binary wire protocol over TCP —
  pipelined sessions, 429-style backpressure replies, graceful drain.
* the RPC shard data plane (`rpc`): each ShardWorker behind its own
  `WorkerServer` (SHARD_QUERY/SHARD_RESULT/CANCEL frames), the frontend
  dials a reconnecting `WorkerPool` of `WorkerChannel`s, and
  `RpcFrontend` scatters every shard dispatch as a real hedged RPC —
  duplicate backups on the wall clock, losers cancelled on the wire.
* the offline bulk lane (`bulk`): `BulkLane` sweeps whole query sets
  shard-major (each tile staged into HBM once, amortized over every
  query) in the interactive lane's idle time, with per-shard
  checkpoints and a `BULK` wire frame for remote submission.
* the observability plane (`repro.obs`, threaded through every layer):
  request traces with per-stage spans (trace ids ride the wire protocol
  end to end), the metrics registry behind `ServingMetrics` with a
  Prometheus text exporter and a `STATS` frame, kernel profiling that
  feeds the autotuner live cost observations, and a slow-query JSONL
  event log replayable by `benchmarks/trace_report.py`.
* LM inference steps (`step`) for the model substrate: prefill/decode and
  the greedy generation driver.
"""
from ..obs import (EventLog, KernelProfiler, MetricsRegistry, Span, Trace,
                   Tracer, render_prometheus)
from .batcher import MicroBatch, MicroBatcher, fit_bucket_edges
from .bulk import BulkJob, BulkLane, BulkStatus
from .cache import LRUCache, result_key, term_key
from .frontend import Frontend, FrontendConfig
from .loop import LoopClosed, ServingLoop
from .metrics import MetricsSnapshot, ServingMetrics
from .net import NetClient, NetResult, NetServer
from .planner import QueryPlan, QueryPlanner
from .request import QueryRequest, QueryResponse, Status
from .rpc import (ChannelDown, RpcError, RpcFrontend, WorkerChannel,
                  WorkerPool, WorkerServer)
from .server import QueryServer, ServerConfig
from .step import make_prefill_step, make_decode_step, greedy_generate
from .worker import DispatchCancelled, ShardWorker

__all__ = [
    "MicroBatch", "MicroBatcher", "fit_bucket_edges",
    "BulkJob", "BulkLane", "BulkStatus",
    "LRUCache", "result_key", "term_key",
    "MetricsSnapshot", "ServingMetrics", "QueryPlan", "QueryPlanner",
    "QueryRequest", "QueryResponse", "Status", "QueryServer", "ServerConfig",
    "Frontend", "FrontendConfig", "ShardWorker", "DispatchCancelled",
    "LoopClosed", "ServingLoop", "NetClient", "NetResult", "NetServer",
    "ChannelDown", "RpcError", "RpcFrontend", "WorkerChannel",
    "WorkerPool", "WorkerServer",
    "EventLog", "KernelProfiler", "MetricsRegistry", "Span", "Trace",
    "Tracer", "render_prometheus",
    "make_prefill_step", "make_decode_step", "greedy_generate",
]
