"""QueryServer: the serving front-end tying planner, batcher, caches and
metrics together.

Life of a request:

1. ``submit`` compiles the pattern to distinct packed terms, answers
   immediately on a result-cache hit, a single-term row-cache hit, or
   backpressure (queue full), and otherwise enqueues into the
   shape-bucketed micro-batcher.
2. ``step`` (called from the driver's loop) polls the batcher; every due
   micro-batch is planned (kernel choice from index layout x batch shape),
   scored in one device call, split back into per-request results with the
   request's own threshold, and cached.
3. Responses accumulate until ``pop_responses``.

The server is single-threaded and clock-injectable: drivers decide the
cadence (closed-loop benchmarks call ``drain``; open-loop ones call
``step`` on arrival timestamps), and tests run on a virtual clock.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..core import codec as _codec
from ..core import hashing
from ..core.arena import DeviceTileCache, common_tile_rows
from ..core.index import BitSlicedIndex
from ..core.query import (PruneStats, SearchResult, compile_pattern,
                          coverage_cutoff, plan_dedup_batch, run_paged,
                          run_paged_compressed, run_paged_dedup,
                          run_paged_pruned, select_hits, select_top_k)
from ..kernels.autotune import KernelTuner, TuningCache
from ..obs import EventLog, KernelProfiler, Tracer
from ..obs.profile import gather_bytes
from .base import ServingBackend
from .batcher import MicroBatch, MicroBatcher
from .cache import LRUCache, result_key, term_key
from .metrics import ServingMetrics
from .planner import DEFAULT_DEDUP_MIN_RATE, QueryPlanner
from .request import QueryRequest, QueryResponse, Status


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    term_pad: int = 64          # bucket granularity (multiples of this)
    max_batch: int = 32         # micro-batch cap per bucket
    max_wait_s: float = 0.002   # flush timer for partially-filled buckets
    max_queued: int = 1024      # backpressure cap across all buckets
    # Fit bucket boundaries to the observed term-length histogram instead
    # of the fixed term_pad grid (MicroBatcher adaptive mode): workloads
    # whose query lengths cluster between grid lines batch densely.
    adaptive_buckets: bool = False
    result_cache: int = 1024    # whole-query LRU entries (0 disables)
    row_cache: int = 4096       # single-term row LRU entries (0 disables)
    default_threshold: float = 0.8
    # HBM budget for arena shard tiles when serving an out-of-core
    # (sharded/mmapped) index; None = unbounded, every touched shard stays
    # resident. Ignored for dense single-shard storage.
    tile_cache_bytes: Optional[int] = None
    # Kernel tile width for every dispatched scoring kernel. None = the
    # autotuner's measured choice when tuning is wired in, else the kernel
    # default (kernels.bitslice_score.DEFAULT_WORD_BLOCK).
    word_block: Optional[int] = None
    # Row-dedup path: minimum fraction of a batch's row gathers that must
    # be duplicates before the dedup pair replaces the fused multi-query
    # kernel. None disables dedup; a tuner-measured break-even overrides
    # this default.
    dedup_min_rate: Optional[float] = DEFAULT_DEDUP_MIN_RATE
    # Serve dict-coded shards from their compressed (dict, refs) device
    # form through the fused-decode kernels. The planner still decides
    # per batch shape (measured lookup-vs-lookup_c cost, or the dict
    # ratio heuristic); raw shards and all-raw stores are unaffected.
    compressed: bool = False
    # Threshold-driven pruned scoring: batches whose coverage threshold
    # predicts enough block pruning run through the chunked early-exit
    # executor (rarest-first term chunks, per-block bound, pruned blocks
    # skip all further tile I/O/staging/kernel work). The planner still
    # gates per batch on the tuned (or heuristic) break-even — results
    # stay bit-identical to unpruned scoring either way.
    pruned: bool = False
    prune_chunk: int = 32
    # Minimum predicted block-prune rate before pruned dispatch, when no
    # measured break-even exists (None = planner.DEFAULT_PRUNE_MIN_RATE).
    prune_min_rate: Optional[float] = None
    # Autotune kernel configs on demand per batch shape (measured costs
    # drive the planner; entries persist in tuning_cache). False with a
    # tuning_cache still CONSULTS existing entries — it just never
    # measures in the serving path.
    autotune: bool = False
    # Path of the persisted tuning cache (JSON; by convention
    # repro.core.store.tuning_path(store_dir) = beside the v2 manifest).
    # None keeps tuned entries in memory only.
    tuning_cache: Optional[str] = None
    # -- observability (repro.obs) --
    # Request tracing: every admitted query gets a Trace; layers append
    # spans; finished traces land in a bounded ring. Cheap enough to
    # default on (two clock reads + a locked append per span).
    tracing: bool = True
    # Completed traces slower than this (ms, end to end) go to the
    # slow-query JSONL log. 0 disables the slow sink (ring still fills).
    trace_slow_ms: float = 0.0
    trace_ring: int = 256
    # JSONL slow-query log path; None keeps events in memory only.
    trace_log: Optional[str] = None
    # Per-dispatch kernel wall time + bytes-moved accounting, fed to the
    # metrics registry and (when a tuner is wired) back into the tuning
    # cache as live observed-cost entries.
    profile_kernels: bool = True


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class QueryServer(ServingBackend):
    def __init__(self, index: BitSlicedIndex,
                 config: ServerConfig = ServerConfig(), *,
                 clock: Callable[[], float] = time.monotonic):
        self.index = index
        self.config = config
        self.clock = clock
        # Tuned kernel configs: with a cache path wired in, entries load
        # from disk and serving never re-tunes what is already measured;
        # autotune=True additionally measures misses on demand.
        self.tuner: Optional[KernelTuner] = None
        if config.autotune or config.tuning_cache:
            self.tuner = KernelTuner.for_index(
                index, TuningCache(config.tuning_cache),
                enabled=config.autotune)
        self.planner = QueryPlanner(index, tuner=self.tuner,
                                    word_block=config.word_block,
                                    dedup_min_rate=config.dedup_min_rate,
                                    compressed=config.compressed,
                                    pruned=config.pruned,
                                    prune_chunk=config.prune_chunk,
                                    prune_min_rate=config.prune_min_rate)
        # Whole-arena HBM footprint: the baseline a pruned batch's actual
        # bytes-read is charged against for the bytes-saved metric.
        self._arena_total_bytes = sum(
            int(index.storage.shard_hbm_nbytes(s))
            for s in range(index.storage.n_shards))
        self.batcher = MicroBatcher(
            term_pad=config.term_pad, max_batch=config.max_batch,
            max_wait_s=config.max_wait_s, max_queued=config.max_queued,
            adaptive=config.adaptive_buckets)
        self.metrics = ServingMetrics()
        self.results_cache = LRUCache(config.result_cache)
        self.rows_cache = LRUCache(config.row_cache)
        self._responses: dict[int, QueryResponse] = {}
        self._next_id = 0
        self._host_slot = np.asarray(index.layout.doc_slot)
        # Out-of-core serving state: shard tiles are paged into HBM through
        # a bounded LRU; with dense storage there is exactly one "shard"
        # (the resident arena) and the cache is a pass-through.
        self.tiles = DeviceTileCache(index.storage,
                                     capacity_bytes=config.tile_cache_bytes,
                                     pad_rows_to=common_tile_rows(
                                         index.storage))
        self._shard_args = [(sp.shard, jnp.asarray(sp.row_offset),
                             jnp.asarray(sp.block_width))
                            for sp in self.planner.shard_plans]
        # -- observability ---------------------------------------------------
        self.events = EventLog(config.trace_log,
                               ring=max(64, config.trace_ring))
        self.tracer = Tracer(enabled=config.tracing,
                             ring=config.trace_ring,
                             slow_ms=config.trace_slow_ms,
                             sink=self.events, clock=clock)
        self.metrics.tracer = self.tracer
        self.profiler = KernelProfiler(self.metrics.registry, self.tuner,
                                       enabled=config.profile_kernels)
        # Tile-cache events flow through one observer: per-shard labeled
        # counters always; per-batch fault/prefetch capture so the kernel
        # span can name the shards it had to stage.
        self._tile_events: list[tuple] = []
        self.tiles.observer = self._on_tile_event
        # Compressed-arena accounting: host-side decodes land in the
        # decode histogram; staged bytes are read as per-batch deltas of
        # the tile cache's per-form counters in score_batch.
        if hasattr(index.storage, "decode_observer"):
            index.storage.decode_observer = \
                lambda s, codec, sec: self.metrics.record_decode(sec)

    def _on_tile_event(self, shard: int, event: str,
                       seconds: float) -> None:
        self.metrics.record_shard_tile(shard, event)
        if event in ("fault", "prefetch"):
            self._tile_events.append((shard, event, self.clock(), seconds))

    # -- submission ---------------------------------------------------------
    def submit(self, pattern=None, *, terms: Optional[np.ndarray] = None,
               threshold: Optional[float] = None,
               top_k: Optional[int] = None,
               deadline: Optional[float] = None,
               trace_id: int = 0) -> int:
        """Accept one query (pattern or precompiled terms); returns the
        request id. ``top_k`` switches the request from coverage-threshold
        selection to exact top-k (same total order as QueryEngine.top_k).
        Fast paths answer immediately; everything else lands in the
        micro-batcher until the next ``step``/``drain``. ``trace_id``
        propagates a caller-minted id (the wire layer's) into the
        request's trace; 0 mints a fresh one when tracing is on."""
        if (pattern is None) == (terms is None):
            raise ValueError("pass exactly one of pattern / terms")
        if terms is None:
            terms = compile_pattern(pattern, self.index.params)
        threshold = (self.config.default_threshold if threshold is None
                     else threshold)
        top_k = int(top_k) if top_k else 0
        now = self.clock()
        rid = self._next_id
        self._next_id += 1
        ell = terms.shape[0]
        trace = self.tracer.begin(rid, trace_id=trace_id or None,
                                  started_s=now)

        if ell == 0:
            empty = SearchResult(np.zeros(0, np.int32),
                                 np.zeros(0, np.int32), 0, 0)
            if trace is not None:
                trace.add("fast_path", now, self.clock(),
                          {"path": "empty"})
            self._answer(rid, Status.OK, empty, wait=0.0, service=0.0,
                         trace=trace)
            return rid

        key = result_key(terms, threshold, top_k)
        hit = self.results_cache.get(key)
        if hit is not None:
            self.metrics.record_request(wait_s=0.0, service_s=0.0,
                                        cached=True)
            if trace is not None:
                trace.add("cache_lookup", now, self.clock(), {"hit": 1})
            self._responses[rid] = self._finalize(trace, QueryResponse(
                rid, Status.OK, hit, method="cache", batch_size=1,
                cached=True))
            return rid

        if ell == 1 and self.rows_cache.capacity:
            result, row_hit = self._point_query(terms, threshold, top_k)
            service = self.clock() - now
            self.metrics.record_request(wait_s=0.0, service_s=service,
                                        cached=row_hit)
            if trace is not None:
                trace.add("point_query", now, self.clock(),
                          {"row_hit": int(row_hit)})
            self._responses[rid] = self._finalize(trace, QueryResponse(
                rid, Status.OK, result, method="row_cache", batch_size=1,
                wait_s=0.0, service_s=service, cached=row_hit))
            self.results_cache.put(key, result)
            return rid

        req = QueryRequest(rid, terms, ell, threshold,
                           submitted_at=now, deadline=deadline,
                           top_k=top_k, trace=trace)
        if not self.batcher.submit(req):
            self.metrics.record_rejected()
            if trace is not None:
                trace.add("reject", now, self.clock(),
                          {"reason": "backpressure"})
            self._responses[rid] = self._finalize(
                trace, QueryResponse(rid, Status.REJECTED))
            return rid
        return rid

    def _finalize(self, trace, resp: QueryResponse) -> QueryResponse:
        return self.finalize_trace(trace, resp)

    # -- point queries (COBS single-k-mer lookups) via the row cache --------
    def _gather_host_row(self, term: np.ndarray) -> np.ndarray:
        """ANDed arena row for one term, host-side: uint32 [nb * W] in
        slot-word order (mirrors plan_rows + gather exactly). Reads rows
        through the storage backend, so an mmapped index pages in only the
        touched shards — the dense arena is never materialized here."""
        h = hashing.hash_terms_np(term[None, :],
                                  self.index.params.n_hashes)[0]  # [k]
        layout = self.index.layout
        rows = (h[:, None] % layout.block_width.astype(np.uint32)
                + layout.row_offset.astype(np.uint32))            # [k, nb]
        g = self.index.storage.read_rows_host(rows.astype(np.int64))
        anded = g[0]                                              # [nb, W]
        for i in range(1, g.shape[0]):
            anded = anded & g[i]
        return anded.reshape(-1)                                  # [nb * W]

    def _point_query(self, terms: np.ndarray, threshold: float,
                     top_k: int = 0) -> tuple[SearchResult, bool]:
        """Returns (result, served-from-row-cache)."""
        k = term_key(terms[0])
        row = self.rows_cache.get(k)
        hit = row is not None
        if row is None:
            row = self._gather_host_row(terms[0])
            self.rows_cache.put(k, row)
        bits = ((row[:, None] >> np.arange(32, dtype=np.uint32)) & 1)
        scores = bits.astype(np.int32).reshape(-1)[self._host_slot]
        return self._select(scores, 1, threshold, top_k), hit

    @staticmethod
    def _select(scores: np.ndarray, n_terms: int, threshold: float,
                top_k: int) -> SearchResult:
        """Per-request selection: coverage threshold, or exact top-k under
        QueryEngine's (-score, doc id) total order when top_k > 0."""
        if top_k:
            return select_top_k(scores, n_terms, top_k)
        return select_hits(scores, n_terms, threshold)

    # -- batch scoring -------------------------------------------------------
    def _run_plan(self, plan, fn, terms_dev, valid_dev,
                  fn_comp=None) -> np.ndarray:
        """Dispatch ``fn`` once against the dense arena, or — for a paged
        plan — once per shard tile (staged through the LRU tile cache),
        concatenating per-shard slot scores along the slot axis. With
        ``fn_comp`` (compressed plans) dict-coded shards stage their
        (dict, refs) form and score through the fused-decode kernels."""
        if not plan.paged:
            # tiles.get(0) caches the device copy for every backend (a
            # single-shard MappedArena would otherwise re-upload per batch)
            if (fn_comp is not None and self.index.storage.shard_codec(0)
                    in _codec.DICT_CODECS):
                dict_rows, refs = self.tiles.get_compressed(0)
                out = fn_comp(dict_rows, refs, self.index.row_offset,
                              self.index.block_width, terms_dev, valid_dev)
            else:
                out = fn(self.tiles.get(0), self.index.row_offset,
                         self.index.block_width, terms_dev, valid_dev)
            return np.asarray(out)
        if fn_comp is not None:
            return np.concatenate(
                run_paged_compressed(self.tiles, self._shard_args, fn,
                                     fn_comp, terms_dev, valid_dev),
                axis=-1)
        return np.concatenate(
            run_paged(self.tiles, self._shard_args, fn, terms_dev,
                      valid_dev), axis=-1)

    def _score_dedup(self, buf: np.ndarray, n_valid: np.ndarray, plan,
                     marks: Optional[list] = None) -> Optional[np.ndarray]:
        """Row-dedup dispatch, or None when the batch's measured dedup
        rate is below the plan's break-even threshold. The global-layout
        plan decides; dense execution reuses it directly, paged execution
        re-plans per shard against the rebased addressing. ``marks``
        collects (name, start, end, tags) stage timings for tracing."""
        layout = self.index.layout
        td0 = self.clock()
        dp = plan_dedup_batch(buf, n_valid, layout.row_offset,
                              layout.block_width)
        if marks is not None:
            marks.append(("dedup_plan", td0, self.clock(),
                          {"dedup_rate": round(float(dp.dedup_rate), 4),
                           "n_unique": int(dp.n_unique)}))
        if dp.dedup_rate < plan.dedup_threshold:
            return None
        fn = self.planner.dedup_score_fn(plan)
        fn_comp = (self.planner.comp_dedup_score_fn(plan)
                   if plan.compressed else None)
        tk0 = self.clock()
        if not plan.paged:
            planned = (jnp.asarray(dp.uniq_rows), jnp.asarray(dp.indir),
                       jnp.asarray(dp.mask))
            if (fn_comp is not None and self.index.storage.shard_codec(0)
                    in _codec.DICT_CODECS):
                dict_rows, refs = self.tiles.get_compressed(0)
                slots = np.asarray(fn_comp(dict_rows, refs, *planned))
            else:
                slots = np.asarray(fn(self.tiles.get(0), *planned))
        else:
            slots = run_paged_dedup(self.tiles, self.planner.shard_plans,
                                    fn, buf, n_valid, fn_comp=fn_comp)
        tk1 = self.clock()
        self._kernel_mark(marks, "dedup_c" if plan.compressed else "dedup",
                          plan, tk0, tk1, rows=int(dp.uniq_rows.shape[0]))
        return slots

    def _kernel_mark(self, marks: Optional[list], method: str, plan,
                     t0: float, t1: float, *, rows: int) -> None:
        """Record one kernel dispatch: trace mark (with the shards the
        tile cache had to stage mid-dispatch), profiler histogram, and
        the live cost signal for the autotuner."""
        moved = gather_bytes(rows, int(self.index.storage.shape[1]))
        if marks is not None:
            tags = {"method": method, "bucket": plan.bucket,
                    "word_block": plan.word_block or 0,
                    "bytes_moved": moved}
            faulted = sorted({s for s, ev, _, _ in self._tile_events
                              if ev == "fault"})
            if faulted:
                tags["faulted_shards"] = faulted
            marks.append(("kernel_score", t0, t1, tags))
        self.profiler.record(
            method=method, bucket=plan.bucket, batch=plan.batch_size,
            seconds=t1 - t0, word_block=plan.word_block or 0,
            term_block=plan.term_block or 0, grid_order=plan.grid_order,
            bytes_moved=moved)

    def score_batch(self, batch: MicroBatch) -> None:
        """Plan, dispatch, and answer one flushed micro-batch. Public so
        an active serving loop (repro.serve.loop) can pull batches off
        ``poll_batches`` and score them from worker threads."""
        t0 = self.clock()
        Q, B = batch.size, batch.bucket
        traced = any(r.trace is not None for r in batch.requests)
        marks: Optional[list] = [] if traced else None
        self._tile_events = []
        nb = self.index.layout.n_blocks
        tp0 = self.clock()
        # The weakest coverage threshold across the batch is the bound
        # every block must clear for at least one request — the planner's
        # basis for predicting the prune rate. All-top-k batches pass
        # None (still correct to prune via the dynamic bound, but with no
        # static prediction the planner stays unpruned).
        thr_hint = min((r.threshold for r in batch.requests if not r.top_k),
                       default=None)
        plan = self.planner.plan(B, Q, threshold=thr_hint)
        if marks is not None:
            marks.append(("plan", tp0, self.clock(),
                          {"method": plan.method, "fused": int(plan.fused),
                           "paged": int(plan.paged),
                           "pruned": int(plan.pruned)}))
        # compressed fused dispatch reports (and live-profiles) as
        # "lookup_c" — the tuner's cost key for the decode-in-the-loop
        # kernel, keeping observed costs per path
        method = ("lookup_c" if plan.compressed and plan.method == "lookup"
                  else plan.method)
        ells = np.array([r.n_terms for r in batch.requests], dtype=np.int32)
        tiles0 = (self.tiles.hits, self.tiles.faults,
                  self.tiles.prefetched, self.tiles.prefetch_hits)
        bytes0 = (self.tiles.raw_bytes_staged, self.tiles.comp_bytes_staged)
        if plan.pruned:
            # Chunked branch-and-bound executor: rarest-first term chunks
            # against a persistent running-count buffer; blocks whose
            # bound falls below the coverage cutoff (or the running k-th
            # score) skip all further gathers, staging and kernel work.
            # Bit-identical to the unpruned paths by construction.
            q_pad = 1 if Q == 1 else _next_pow2(Q)
            buf = np.zeros((q_pad, B, 2), dtype=np.uint32)
            n_valid = np.zeros(q_pad, dtype=np.int32)
            required = np.full(q_pad, np.iinfo(np.int32).max,
                               dtype=np.int64)
            topks = np.zeros(q_pad, dtype=np.int32)
            for i, r in enumerate(batch.requests):
                buf[i, : r.n_terms] = r.terms
                n_valid[i] = r.n_terms
                topks[i] = r.top_k
                required[i] = (0 if r.top_k else
                               coverage_cutoff(r.threshold, r.n_terms))
            method = "lookup_p"
            pstats = PruneStats()
            tk0 = self.clock()
            slots = run_paged_pruned(
                self.tiles, self.planner.shard_plans, buf, n_valid,
                required, topks, n_hashes=self.index.params.n_hashes,
                chunk_terms=plan.chunk_terms or self.config.prune_chunk,
                word_block=plan.word_block, stats=pstats)
            tk1 = self.clock()
            w = int(self.index.storage.shape[1])
            self._kernel_mark(marks, method, plan, tk0, tk1,
                              rows=max(1, pstats.bytes_read // (4 * w)))
            self.metrics.record_prune(
                blocks_total=pstats.blocks_total,
                blocks_pruned=pstats.blocks_pruned,
                tiles_skipped=pstats.shard_visits_skipped,
                bytes_saved=max(
                    0, self._arena_total_bytes - pstats.bytes_read))
            if marks is not None:
                marks.append(("prune", tk0, tk1, {
                    "blocks_pruned": int(pstats.blocks_pruned),
                    "blocks_total": int(pstats.blocks_total),
                    "tiles_skipped": int(pstats.shard_visits_skipped),
                    "bytes_read": int(pstats.bytes_read),
                    "predicted": round(float(plan.predicted_prune), 3)}))
            scores = slots[:Q][:, self._host_slot]
        elif Q == 1:
            buf = np.zeros((B, 2), dtype=np.uint32)
            buf[: ells[0]] = batch.requests[0].terms
            fn = self.planner.single_score_fn(plan)
            fn_comp = (self.planner.comp_single_score_fn(plan)
                       if plan.compressed else None)
            tk0 = self.clock()
            slots = self._run_plan(plan, fn, jnp.asarray(buf),
                                   jnp.int32(ells[0]), fn_comp=fn_comp)
            self._kernel_mark(marks, method, plan, tk0, self.clock(),
                              rows=B * nb)
            scores = slots[None, self._host_slot]
        else:
            # Pad the query axis to a power of two so jit entries stay
            # bounded at (buckets x log2 max_batch) rather than one per
            # observed batch size.
            q_pad = _next_pow2(Q)
            buf = np.zeros((q_pad, B, 2), dtype=np.uint32)
            for i, r in enumerate(batch.requests):
                buf[i, : r.n_terms] = r.terms
            n_valid = np.zeros(q_pad, dtype=np.int32)
            n_valid[:Q] = ells
            slots = None
            if plan.fused and plan.dedup_threshold is not None:
                slots = self._score_dedup(buf, n_valid, plan, marks)
                if slots is not None:
                    method = "dedup_c" if plan.compressed else "dedup"
            if slots is None:
                fn = self.planner.batch_score_fn(plan)
                fn_comp = (self.planner.comp_batch_score_fn(plan)
                           if plan.compressed else None)
                tk0 = self.clock()
                slots = self._run_plan(plan, fn, jnp.asarray(buf),
                                       jnp.asarray(n_valid),
                                       fn_comp=fn_comp)
                self._kernel_mark(marks, method, plan, tk0, self.clock(),
                                  rows=q_pad * nb * B)
            scores = slots[:Q][:, self._host_slot]
        t1 = self.clock()
        service = t1 - t0

        if marks is not None:
            # tile stagings observed during this batch's dispatches, as
            # their own spans naming the shard (demand fault vs prefetch)
            for s, ev, t_end, dur in self._tile_events:
                marks.append(("tile_fetch", t_end - dur, t_end,
                              {"shard": s, "event": ev}))
        self.planner.record(plan, method)
        self.metrics.record_batch(Q, self.batcher.occupancy(batch), method)
        self.metrics.record_arena_bytes(
            raw=self.tiles.raw_bytes_staged - bytes0[0],
            comp=self.tiles.comp_bytes_staged - bytes0[1])
        if plan.paged:
            self.metrics.record_tiles(
                hits=self.tiles.hits - tiles0[0],
                faults=self.tiles.faults - tiles0[1],
                resident=len(self.tiles),
                prefetched=self.tiles.prefetched - tiles0[2],
                prefetch_hits=self.tiles.prefetch_hits - tiles0[3])
        for i, r in enumerate(batch.requests):
            ts0 = self.clock()
            result = self._select(scores[i], r.n_terms, r.threshold,
                                  r.top_k)
            wait = max(0.0, t0 - r.submitted_at)
            self.metrics.record_request(wait_s=wait, service_s=service)
            resp = QueryResponse(
                r.request_id, Status.OK, result, method=method,
                batch_size=Q, wait_s=wait, service_s=service)
            if r.trace is not None:
                r.trace.add("queue_wait", r.submitted_at, t0,
                            {"flush": batch.reason or "direct",
                             "batch_size": Q})
                for name, ms, me, tags in marks:
                    r.trace.add(name, ms, me, tags)
                r.trace.add("select", ts0, self.clock())
                self.finalize_trace(r.trace, resp)
            self._responses[r.request_id] = resp
            self.results_cache.put(
                result_key(r.terms, r.threshold, r.top_k), result)

    def _answer(self, rid: int, status: Status, result, *, wait: float,
                service: float, trace=None) -> None:
        self.metrics.record_request(wait_s=wait, service_s=service)
        self._responses[rid] = self._finalize(trace, QueryResponse(
            rid, status, result, wait_s=wait, service_s=service))

    # -- serving loop (poll_batches / step / drain / take_response /
    # retract / pop_responses come from ServingBackend) ----------------------
    def reset_metrics(self, *, clear_caches: bool = False) -> None:
        """Fresh counters (drivers call this after jit warmup so compile
        time does not pollute the latency percentiles). clear_caches=True
        also empties the result/row caches — needed when the warmup replays
        the measurement workload, which would otherwise be served entirely
        from cache."""
        self.metrics = ServingMetrics()
        self.metrics.tracer = self.tracer
        self.profiler.bind_registry(self.metrics.registry)
        self.planner.dispatch_counts.clear()
        if clear_caches:
            self.results_cache = LRUCache(self.results_cache.capacity)
            self.rows_cache = LRUCache(self.rows_cache.capacity)
