"""Serving steps: prefill (parallel forward filling caches) and decode (one
token against a seq_len cache). These are what the decode_*/long_* dry-run
shapes lower; greedy_generate stitches them for the examples/tests.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..models.transformer import Model


def make_prefill_step(model: Model, cache_len: int, last_only: bool = True):
    def prefill_step(params, batch):
        """batch: {"tokens": [B, S], optional "enc_feats"} ->
        (logits, caches). last_only=True returns [B, 1, V] — serving only
        needs the next-token distribution, and materializing the full
        [B, S, V] prefill logits costs hundreds of GB at 32k."""
        logits, caches = model.prefill(params, batch["tokens"], cache_len,
                                       enc_feats=batch.get("enc_feats"))
        if last_only:
            logits = logits[:, -1:, :]
        return logits, caches
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, caches, tokens, pos):
        """tokens [B, 1], pos int32 [] -> (logits [B, 1, V], caches)."""
        return model.decode_step(params, caches, tokens, pos)
    return decode_step


def greedy_generate(model: Model, params, prompt: jnp.ndarray, n_new: int,
                    cache_len: int, *, enc_feats=None):
    """Greedy decoding driver (host loop, jitted steps): returns
    [B, S + n_new] token matrix."""
    B, S = prompt.shape
    prefill = jax.jit(make_prefill_step(model, cache_len, last_only=False))
    decode = jax.jit(make_decode_step(model))
    logits, caches = prefill(params, {"tokens": prompt,
                                      "enc_feats": enc_feats})
    tokens = [prompt]
    last = logits[:, -1:].argmax(-1).astype(prompt.dtype)
    for i in range(n_new):
        tokens.append(last)
        if i == n_new - 1:
            break
        logits, caches = decode(params, caches, last,
                                jnp.asarray(S + i, jnp.int32))
        last = logits[:, -1:].argmax(-1).astype(prompt.dtype)
    return jnp.concatenate(tokens, axis=1)
