"""ShardWorker: the per-host half of the sharded serving data plane.

A worker owns a sub-store view (``repro.core.store.open_substore``) of the
shard files its ``ShardPlacement`` replica set assigns to it — it never
maps, stages, or scores any other part of the index. Per dispatch it
receives one micro-batch (padded term buffer + validity counts) and one
GLOBAL shard id from its replica set, scores that shard's tile through the
same Pallas kernels as the single-host engine (kernel choice =
``repro.serve.planner.choose_method``, so the dispatch mix matches), and
compresses the [Q, shard_slots] score plane into per-query CANDIDATES:

* threshold mode — every (doc, score) of its blocks with
  score >= ceil(K * ell) (the paper's coverage cutoff);
* top-k mode    — its k best documents under the engine's exact total
  order (descending score, ties ascending doc id).

Candidate sets are what crosses the host boundary: the frontend gathers
them and runs the final selection exactly like ``index/distributed.py``'s
score-combine, so the gathered result is bit-identical to the single-host
QueryEngine (property-tested in tests/test_multihost.py).

Tiles page through a per-worker ``DeviceTileCache`` (HBM budget per host)
padded to the PARENT store's tallest shard, so every worker shares one
compiled kernel per (bucket, method); ``prefetch_shard`` lets the frontend
double-buffer the next planned shard while another worker scores.

``fail()``/``recover()`` flip a liveness flag: a dead worker raises
``AttemptFailed`` on dispatch, which the frontend's HedgedExecutor turns
into failover to the next replica.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import codec as _codec
from ..core.query import (PruneStats, ShardPlan, make_batch_score_fn,
                          make_comp_batch_score_fn, plan_shards_subset,
                          run_paged_pruned)
from ..core.store import open_substore
from ..core.arena import DeviceTileCache
from ..index.hedge import AttemptFailed
from .planner import (DEFAULT_PRUNE_MIN_RATE, SHORT_QUERY_TERMS,
                      choose_method, predict_prune_rate)

# One compiled scorer per (n_hashes, method, word_block), shared by EVERY
# worker in the process: fake hosts pad tiles to the parent store's tallest
# shard, so their dispatch shapes coincide and recompiling per worker would
# only burn startup time (noticeable across the elasticity property sweeps).
_SCORE_FNS: dict[tuple[int, str, Optional[int]], object] = {}
# ... and the fused-decode twins for workers serving compressed shards.
_SCORE_FNS_C: dict[tuple[int, str, Optional[int]], object] = {}


def _shared_score_fn(n_hashes: int, method: str,
                     word_block: Optional[int] = None):
    fn = _SCORE_FNS.get((n_hashes, method, word_block))
    if fn is None:
        fn = make_batch_score_fn(n_hashes, method, word_block=word_block)
        _SCORE_FNS[(n_hashes, method, word_block)] = fn
    return fn


def _shared_comp_score_fn(n_hashes: int, method: str,
                          word_block: Optional[int] = None):
    fn = _SCORE_FNS_C.get((n_hashes, method, word_block))
    if fn is None:
        fn = make_comp_batch_score_fn(n_hashes, method,
                                      word_block=word_block)
        _SCORE_FNS_C[(n_hashes, method, word_block)] = fn
    return fn


class DispatchCancelled(Exception):
    """A dispatch's cancellation flag fired (a hedged duplicate of the
    request already won elsewhere) — the worker stops scoring and the
    RPC plane answers SHARD_CANCELLED instead of a candidate set."""


class ShardWorker:
    """One fake/real host serving a subset of a v2 store's shards."""

    def __init__(self, name: str, store, shard_ids, *,
                 tile_cache_bytes: Optional[int] = None,
                 verify: bool = False, device=None,
                 short_query_terms: int = SHORT_QUERY_TERMS,
                 word_block: Optional[int] = None,
                 compressed: bool = False,
                 pruned: bool = False, prune_chunk: int = 32,
                 prune_min_rate: Optional[float] = None,
                 local_pad: bool = False, tuner=None):
        sub = open_substore(store, shard_ids, verify=verify)
        self.name = name
        self.layout = sub.layout            # FULL store layout (metadata)
        self.storage = sub.storage          # only this host's shard files
        self.params = sub.params
        self.shard_ids = sub.shard_ids
        self.device = device
        self.short_query_terms = short_query_terms
        # kernel tile width for every dispatch (ServerConfig.word_block /
        # the autotuner's choice, threaded from the launcher); None = the
        # kernel default
        self.word_block = word_block
        # Serve dict-coded shards from their compressed (dict, refs)
        # device form through the fused-decode kernels; raw shards on the
        # same worker keep the raw path. Candidates are bit-identical —
        # only this host's HBM working set changes.
        self.compressed = bool(compressed)
        self.compressed_dispatches = 0
        self._local = {g: i for i, g in enumerate(self.shard_ids)}
        self.plans: list[ShardPlan] = plan_shards_subset(
            sub.layout, sub.global_row_starts, sub.shard_ids)
        # pad tiles to the PARENT store's tallest shard: one kernel shape
        # across every worker, not one per host's local maximum.
        # ``local_pad`` instead pads to THIS host's tallest shard — smaller
        # tiles and per-worker dispatch shapes, so a per-worker tuner (the
        # ``tuner`` argument, keyed on the local geometry) can measure each
        # shard height separately instead of one tall-parent tune key
        # covering every worker.
        self.local_pad = bool(local_pad)
        if sub.n_shards_total <= 1:
            pad_rows = None
        elif self.local_pad:
            starts = np.asarray(sub.global_row_starts, dtype=np.int64)
            pad_rows = int(max(starts[g + 1] - starts[g]
                               for g in self.shard_ids))
        else:
            pad_rows = int(np.max(np.diff(sub.global_row_starts)))
        # Optional per-worker KernelTuner (repro.kernels.autotune): its
        # key carries this worker's LOCAL row count, so two workers with
        # different shard heights tune (and cache) separately.
        self.tuner = tuner
        # local-pad shapes differ per worker, so compiled score fns live
        # on the instance instead of the module-level shared caches
        self._fns: dict = {}
        self._fns_c: dict = {}
        # -- pruned (chunked early-exit) candidate scoring ------------------
        self.pruned = bool(pruned)
        self.prune_chunk = int(prune_chunk)
        self.prune_min_rate = (DEFAULT_PRUNE_MIN_RATE
                               if prune_min_rate is None
                               else float(prune_min_rate))
        self.prune_stats = PruneStats()     # cumulative across dispatches
        self.pruned_dispatches = 0
        # cumulative HBM bytes of the shards pruned dispatches covered —
        # what exhaustive scoring would have staged; bytes saved =
        # baseline - prune_stats.bytes_read
        self.prune_baseline_bytes = 0
        w = int(self.storage.shape[1])
        mean_fn = getattr(self.storage, "mean_popcount", None)
        has_fn = getattr(self.storage, "has_popcounts", None)
        if callable(has_fn) and has_fn() and callable(mean_fn) and w:
            self.density = float(mean_fn()) / float(32 * w)
        else:
            self.density = float(self.params.fpr)
        self.tiles = DeviceTileCache(self.storage,
                                     capacity_bytes=tile_cache_bytes,
                                     pad_rows_to=pad_rows, device=device)
        # global slot -> original doc id (-1 for padding slots); workers
        # translate their slot planes to doc candidates host-side
        n_slots = self.layout.n_blocks * self.layout.block_docs
        self._slot_doc = np.full(n_slots, -1, dtype=np.int64)
        self._slot_doc[self.layout.doc_slot] = np.arange(self.layout.n_docs)
        # per-local-shard device-staged addressing
        self._args = [(p.shard, self._dev(p.row_offset),
                       self._dev(p.block_width)) for p in self.plans]
        self.failed = False
        self.dispatches = 0
        # dispatches abandoned mid-tile because their cancellation flag
        # fired (a hedged duplicate won) — the RPC plane's headline
        # "the loser was observably cancelled" counter
        self.cancelled_tiles = 0
        # Optional KernelProfiler (repro.obs.profile): the frontend wires
        # its own in so per-shard kernel timings land in the shared
        # metrics registry tagged with this worker's dispatches.
        self.profiler = None
        # One dispatch at a time per worker: the frontend's concurrent
        # scatter may land two shards on the same host in parallel, and
        # the tile cache / counters are not thread-safe. Serializing per
        # worker models one host's device anyway — the overlap win is
        # ACROSS hosts.
        self._lock = threading.Lock()

    def _dev(self, a: np.ndarray):
        x = jnp.asarray(a)
        return x if self.device is None else jax.device_put(x, self.device)

    # -- liveness (control plane / test hook) -------------------------------
    def fail(self) -> None:
        self.failed = True

    def recover(self) -> None:
        self.failed = False

    def holds(self, gshard: int) -> bool:
        return gshard in self._local

    # -- staging -------------------------------------------------------------
    def stage_batch(self, terms: np.ndarray, n_valid: np.ndarray):
        """Place one micro-batch's buffers on this worker's device. The
        frontend calls this once per (batch, device) and reuses the result
        across every shard dispatch that lands here."""
        return (self._dev(np.asarray(terms)),
                self._dev(np.asarray(n_valid, dtype=np.int32)))

    def prefetch_shard(self, gshard: int) -> bool:
        """Double-buffering hook: stage the tile of global shard
        ``gshard`` host->device without blocking (no-op when resident).
        Compressed workers stage the form they will actually score."""
        if self.failed or gshard not in self._local:
            return False
        local = self._local[gshard]
        with self._lock:
            if self._comp_shard(local):
                return self.tiles.prefetch_compressed(local)
            return self.tiles.prefetch(local)

    # -- scoring -------------------------------------------------------------
    def _score_fn(self, method: str, word_block: Optional[int] = None):
        wb = self.word_block if word_block is None else word_block
        if not self.local_pad:
            return _shared_score_fn(self.params.n_hashes, method, wb)
        key = (self.params.n_hashes, method, wb)
        fn = self._fns.get(key)
        if fn is None:
            fn = make_batch_score_fn(self.params.n_hashes, method,
                                     word_block=wb)
            self._fns[key] = fn
        return fn

    def _comp_score_fn(self, method: str, word_block: Optional[int] = None):
        wb = self.word_block if word_block is None else word_block
        if not self.local_pad:
            return _shared_comp_score_fn(self.params.n_hashes, method, wb)
        key = (self.params.n_hashes, method, wb)
        fn = self._fns_c.get(key)
        if fn is None:
            fn = make_comp_batch_score_fn(self.params.n_hashes, method,
                                          word_block=wb)
            self._fns_c[key] = fn
        return fn

    def _comp_shard(self, local: int) -> bool:
        return (self.compressed and
                self.storage.shard_codec(local) in _codec.DICT_CODECS)

    def score_shard(self, gshard: int, terms_dev, n_valid_dev
                    ) -> tuple[np.ndarray, ShardPlan, str]:
        """Score one held shard against a staged micro-batch. Returns
        (slot scores int32 [Q, shard_slots], the shard's plan, method)."""
        if self.failed:
            raise AttemptFailed(f"worker {self.name} is down")
        local = self._local.get(gshard)
        if local is None:
            raise AttemptFailed(
                f"worker {self.name} does not hold shard {gshard}")
        self.dispatches += 1
        plan = self.plans[local]
        _, offs, widths = self._args[local]
        q, bucket = int(terms_dev.shape[0]), int(terms_dev.shape[1])
        wb = self.word_block
        if self.tuner is not None:
            # per-worker measured costs (keyed on THIS host's geometry)
            entries = self.tuner.costs(bucket, q)
            if not self.compressed:
                entries.pop("lookup_c", None)
            costs = {m: e.cost_us for m, e in entries.items()}
            method = choose_method(self.params.n_hashes, bucket, q,
                                   self.short_query_terms, costs=costs)
            tuned = entries.get(method)
            if method == "lookup_c":
                method = "lookup"
            if wb is None and tuned is not None:
                wb = tuned.word_block
        else:
            method = choose_method(self.params.n_hashes, bucket, q,
                                   self.short_query_terms)
        t0 = time.perf_counter()
        if self._comp_shard(local):
            self.compressed_dispatches += 1
            dict_rows, refs = self.tiles.get_compressed(local)
            fn = self._comp_score_fn(method, wb)
            slots = fn(dict_rows, refs, offs, widths, terms_dev,
                       n_valid_dev)
        else:
            slots = self._score_fn(method, wb)(self.tiles.get(local), offs,
                                               widths, terms_dev,
                                               n_valid_dev)
        slots = np.asarray(slots)
        if self.profiler is not None:
            from ..obs.profile import gather_bytes
            nb_local = int(getattr(plan.row_offset, "shape", (1,))[0])
            self.profiler.record(
                method=method, bucket=bucket, batch=q,
                seconds=time.perf_counter() - t0,
                word_block=wb or 0,
                bytes_moved=gather_bytes(q * nb_local * bucket,
                                         int(self.storage.shape[1])),
                shard=gshard)
        return slots, plan, method

    def _check_cancel(self, cancelled) -> None:
        if cancelled is not None and cancelled():
            self.cancelled_tiles += 1
            raise DispatchCancelled(f"worker {self.name}: dispatch "
                                    f"cancelled between tiles")

    def score_candidates(self, gshard: int, terms_dev, n_valid_dev,
                         cutoffs: np.ndarray, topks: np.ndarray,
                         n_live: int, *, cancelled=None
                         ) -> tuple[list[tuple[np.ndarray, np.ndarray]], str]:
        """Score + select: per live query, the (doc_ids, scores) candidate
        arrays of this shard's documents — hits >= cutoffs[i] when
        topks[i] == 0, else the local top-k under (-score, doc id). Only
        candidates cross the host boundary, O(hits + k) per query instead
        of O(n_docs) — the scatter/gather contract of the frontend.

        With ``pruned`` enabled and the cost model predicting a win, the
        shard dispatch runs through the chunked early-exit executor
        instead: blocks whose bound cannot reach the cutoff skip all
        further gathers and kernel work, a fully-pruned shard never
        stages its tile, and candidates stay bit-identical (pruned
        partial sums are provably below every cutoff)."""
        # ``cancelled`` (optional zero-arg callable) is the RPC plane's
        # cancellation flag: checked before the tile is scored and again
        # before candidate extraction, so a dispatch whose hedged
        # duplicate already won abandons the remaining work and raises
        # DispatchCancelled instead of staging/scanning further.
        self._check_cancel(cancelled)
        with self._lock:
            pr = (self._score_pruned(gshard, terms_dev, n_valid_dev,
                                     cutoffs, topks, n_live)
                  if self.pruned else None)
            if pr is not None:
                slots, plan, method = pr
            else:
                slots, plan, method = self.score_shard(gshard, terms_dev,
                                                       n_valid_dev)
        self._check_cancel(cancelled)
        slot0 = plan.block_start * self.layout.block_docs
        docs = self._slot_doc[slot0: slot0 + slots.shape[1]]
        real = docs >= 0
        docs = docs[real]
        out = []
        for i in range(n_live):
            sc = slots[i][real]
            if topks[i] > 0:
                order = np.lexsort((docs, -sc))[: int(topks[i])]
                out.append((docs[order], sc[order].astype(np.int32)))
            else:
                m = sc >= cutoffs[i]
                out.append((docs[m], sc[m].astype(np.int32)))
        return out, method

    def _score_pruned(self, gshard: int, terms_dev, n_valid_dev,
                      cutoffs: np.ndarray, topks: np.ndarray, n_live: int
                      ) -> Optional[tuple[np.ndarray, ShardPlan, str]]:
        """Chunked early-exit dispatch of one held shard, or None when the
        cost model predicts no win (caller falls back to ``score_shard``).

        Shard-LOCAL top-k pruning is sound here: this worker only reports
        its own shard's top-k candidates, so the dynamic bound needs only
        this shard's running counts. Called under ``self._lock``."""
        if self.failed or gshard not in self._local:
            return None                 # score_shard raises the real error
        bucket = int(terms_dev.shape[1])
        if bucket <= self.prune_chunk:
            return None
        n_valid = np.asarray(n_valid_dev)
        covs = [cutoffs[i] / max(1, int(n_valid[i]))
                for i in range(n_live) if not topks[i]]
        if not covs:
            return None                 # all-top-k: no static prediction
        predicted = predict_prune_rate(float(min(covs)), self.density)
        break_even = self.prune_min_rate
        chunk = min(self.prune_chunk, bucket)
        if self.tuner is not None:
            q = int(terms_dev.shape[0])
            e = self.tuner.entry("lookup_p", bucket, q)
            if e is not None:
                if e.dedup_threshold is not None:
                    break_even = e.dedup_threshold
                chunk = min(e.term_block or chunk, bucket)
        if break_even >= 1.0 or predicted < break_even:
            return None
        local = self._local[gshard]
        plan = self.plans[local]
        self.dispatches += 1
        self.pruned_dispatches += 1
        self.prune_baseline_bytes += int(self.storage.shard_hbm_nbytes(local))
        Q = int(terms_dev.shape[0])
        required = np.full(Q, np.iinfo(np.int32).max, dtype=np.int64)
        for i in range(n_live):
            required[i] = 0 if topks[i] else int(cutoffs[i])
        bytes0 = self.prune_stats.bytes_read
        t0 = time.perf_counter()
        slots = run_paged_pruned(
            self.tiles, [plan], np.asarray(terms_dev), n_valid, required,
            np.asarray(topks, dtype=np.int32),
            n_hashes=self.params.n_hashes, chunk_terms=chunk,
            word_block=self.word_block, stats=self.prune_stats)
        if self.profiler is not None:
            self.profiler.record(
                method="lookup_p", bucket=bucket, batch=Q,
                seconds=time.perf_counter() - t0,
                word_block=self.word_block or 0,
                bytes_moved=self.prune_stats.bytes_read - bytes0,
                shard=gshard)
        return slots, plan, "lookup_p"
