"""Query planner: pick the scoring kernel per micro-batch.

The repo has four scoring methods with very different cost shapes (see
repro.kernels.bitslice_score):

* ``lookup``   — fused gather+score with scalar-prefetched row indices;
  k=1 only. For batches this is the multi-query kernel: one pallas_call
  for the whole [Q, nb, L] batch, shared arena tiles, and no [Q, L, W]
  gathered intermediate. The preferred path whenever it applies.
* ``vertical`` — Harley–Seal bit-sliced counters over a materialized
  gather; O(2 log2 L) vector ops per word. Wins for long queries.
* ``unpack``   — paper-faithful 32-way expansion; O(32) ops per word but
  the lowest fixed cost. Wins for short queries where the fused kernel's
  per-row DMA pipeline and the vertical plane expansion dominate.
* ``ref``      — pure-jnp oracle; never planned, test/debug only.

The planner inspects the index layout ONCE (n_hashes, block count, arena
size) and per batch sees only (bucket = padded term length, batch size),
so a plan is a pure function of a small key — score functions are built
lazily per method and memoized, keeping the jit cache bounded by the
bucket set times the method set.

Layout awareness (out-of-core arenas): when the index storage is sharded
(MappedArena over a cobs-jax-v2 store), the plan is marked ``paged`` and
carries the per-shard addressing (repro.core.query.plan_shards) — the
server then dispatches the planned kernel once per shard tile resident in
the device tile cache and combines slot scores, instead of one call
against a dense arena.
"""
from __future__ import annotations

import dataclasses
from collections import Counter

from ..core.index import BitSlicedIndex
from ..core.query import (ShardPlan, make_batch_score_fn, make_score_fn,
                          plan_shards)

# Below this many (padded) terms the fixed costs dominate and the simple
# unpack expansion is fastest; at/above it Harley–Seal / fused lookup win.
# The crossover in kernels/bitslice_score.py's measurements is ell ~100;
# buckets are multiples of term_pad so the default bites at 64-term pads.
SHORT_QUERY_TERMS = 96


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Dispatch decision for one micro-batch."""
    method: str        # 'lookup' | 'vertical' | 'unpack'
    bucket: int        # padded term length (jit-cache shape key)
    batch_size: int    # live queries in the batch
    fused: bool        # True = single pallas_call for the whole batch
    paged: bool = False  # True = dispatch per shard tile, then combine
    n_shards: int = 1


def choose_method(n_hashes: int, bucket: int, batch_size: int,
                  short_query_terms: int = SHORT_QUERY_TERMS) -> str:
    """The pure kernel-choice rule, shared by the single-host QueryPlanner
    and the multi-host ShardWorker (both must pick the same kernel for the
    same batch shape so dispatch-mix metrics stay comparable)."""
    if batch_size > 1:
        # Batched: the fused multi-query kernel whenever it applies (k=1 —
        # the paper's default); otherwise the gather path, with the ADD
        # kernel picked by query length.
        if n_hashes == 1:
            return "lookup"
        return "unpack" if bucket < short_query_terms else "vertical"
    # Singletons: short queries take the cheap expansion; long ones the
    # fused gather (k=1) or vertical counters.
    if bucket < short_query_terms:
        return "unpack"
    return "lookup" if n_hashes == 1 else "vertical"


class QueryPlanner:
    """Chooses the kernel for each (bucket, batch-size) micro-batch and
    owns the memoized score functions for the methods it dispatches, plus
    the per-shard addressing when the arena storage is sharded."""

    def __init__(self, index: BitSlicedIndex, *,
                 short_query_terms: int = SHORT_QUERY_TERMS):
        self.index = index
        self.short_query_terms = short_query_terms
        self._k = index.params.n_hashes
        self._single_fns: dict[str, object] = {}
        self._batch_fns: dict[str, object] = {}
        self.dispatch_counts: Counter[str] = Counter()
        self.n_shards = index.storage.n_shards
        self.shard_plans: list[ShardPlan] = plan_shards(
            index.layout, index.storage.shard_row_starts)

    # -- planning ----------------------------------------------------------
    def plan(self, bucket: int, batch_size: int) -> QueryPlan:
        """Pure dispatch decision; records nothing."""
        method = choose_method(self._k, bucket, batch_size,
                               self.short_query_terms)
        return QueryPlan(method, bucket, batch_size,
                         fused=(batch_size > 1 and method == "lookup"),
                         paged=self.n_shards > 1, n_shards=self.n_shards)

    # -- score-function cache ---------------------------------------------
    def batch_score_fn(self, plan: QueryPlan):
        """score(arena, row_offset, block_width, terms [Q,L,2], n_valid [Q])
        -> [Q, n_slots] for this plan's method."""
        fn = self._batch_fns.get(plan.method)
        if fn is None:
            fn = make_batch_score_fn(self._k, plan.method)
            self._batch_fns[plan.method] = fn
        return fn

    def single_score_fn(self, plan: QueryPlan):
        fn = self._single_fns.get(plan.method)
        if fn is None:
            fn = make_score_fn(self._k, plan.method)
            self._single_fns[plan.method] = fn
        return fn

    def record(self, plan: QueryPlan) -> None:
        self.dispatch_counts[plan.method] += plan.batch_size

    @property
    def methods_used(self) -> tuple[str, ...]:
        return tuple(sorted(self.dispatch_counts))
