"""Query planner: pick the scoring kernel per micro-batch.

The repo has five scoring paths with very different cost shapes (see
repro.kernels.bitslice_score):

* ``lookup``   — fused gather+score with scalar-prefetched row indices;
  k=1 only. For batches this is the multi-query kernel: one pallas_call
  for the whole [Q, nb, L] batch, shared arena tiles, and no [Q, L, W]
  gathered intermediate.
* ``dedup``    — the batched row-dedup pair riding on ``lookup`` plans:
  unique (block, row) gather + indirected Harley–Seal accumulate, so
  arena DMA traffic scales with UNIQUE rows instead of Q*nb*L. Chosen
  per batch by comparing the batch's measured dedup rate against the
  plan's break-even threshold.
* ``vertical`` — Harley–Seal bit-sliced counters over a materialized
  gather; O(2 log2 L) vector ops per word. Wins for long queries.
* ``unpack``   — paper-faithful 32-way expansion; O(32) ops per word but
  the lowest fixed cost. Wins for short queries where the fused kernel's
  per-row DMA pipeline and the vertical plane expansion dominate.
* ``ref``      — pure-jnp oracle; never planned, test/debug only.

Compressed dispatch: indexes whose shards carry a rowdict codec can be
served from the compressed (dict, refs) device form through fused-decode
kernels (``lookup_c`` in the tuner's cost table). The plan's
``compressed`` flag turns on only when the measured decode-in-the-loop
cost beats the raw fused kernel (or, unmeasured, when the dict ratio
clears ``COMPRESSED_MIN_RATIO``), so a store whose decode cost exceeds
its bandwidth saving transparently keeps the raw path.

Method choice consults MEASURED costs when a ``KernelTuner`` is wired in
(``repro.kernels.autotune``): per (bucket, batch) key the tuner returns
per-method dispatch costs plus the tuned ``word_block`` / ``term_block``
/ ``grid_order`` and the dedup-rate break-even threshold, all persisted
in the on-disk tuning cache (reopening a store never re-tunes). Without
a tuner (or on a cache miss with tuning disabled) the original shape
heuristics apply, so the planner degrades gracefully.

The planner inspects the index layout ONCE (n_hashes, block count, arena
size) and per batch sees only (bucket = padded term length, batch size),
so a plan is a pure function of a small key — score functions are built
lazily per (method, tile config) and memoized, keeping the jit cache
bounded by the bucket set times the config set.

Layout awareness (out-of-core arenas): when the index storage is sharded
(MappedArena over a cobs-jax-v2 store), the plan is marked ``paged`` and
carries the per-shard addressing (repro.core.query.plan_shards) — the
server then dispatches the planned kernel once per shard tile resident in
the device tile cache and combines slot scores, instead of one call
against a dense arena.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Optional

from ..core.index import BitSlicedIndex
from ..core.query import (ShardPlan, make_batch_score_fn,
                          make_comp_batch_score_fn, make_comp_dedup_score_fn,
                          make_comp_score_fn, make_dedup_score_fn,
                          make_score_fn, plan_shards)
from ..kernels.autotune import KernelTuner

# Below this many (padded) terms the fixed costs dominate and the simple
# unpack expansion is fastest; at/above it Harley–Seal / fused lookup win.
# The crossover in kernels/bitslice_score.py's measurements is ell ~100;
# buckets are multiples of term_pad so the default bites at 64-term pads.
SHORT_QUERY_TERMS = 96

# Without measured costs, the dedup path fires when at least this fraction
# of the batch's row gathers are duplicates (a measured break-even from the
# tuner overrides it).
DEFAULT_DEDUP_MIN_RATE = 0.5

# Without measured costs, compressed (fused-decode) dispatch needs at least
# this much HBM dict compression before the decode indirection is presumed
# worth the bandwidth saved; a tuner's measured lookup-vs-lookup_c argmin
# overrides it. Below this the dict barely shrinks the working set and the
# extra scalar gather per row would be pure overhead.
COMPRESSED_MIN_RATIO = 1.25

# Without a measured chunked-vs-fused break-even (the tuner's "lookup_p"
# entry), pruned dispatch needs at least this predicted block-prune rate
# before the chunked executor's extra per-chunk dispatches are presumed
# worth the tile I/O and kernel work they skip.
DEFAULT_PRUNE_MIN_RATE = 0.5


def predict_prune_rate(threshold: float, density: float) -> float:
    """Expected fraction of blocks the bound eliminates, from the query
    coverage threshold and the index's mean slice density (fraction of
    set bits — from the v2 manifest's per-slice popcount stats when
    present, else the configured Bloom FPR).

    Model: a non-matching doc's running count grows ~``density`` per
    term, so after the rarest-first chunks a block with no real match
    sits near ``ell * density`` while the bound demands
    ``ell * threshold`` — the margin (threshold - density) relative to
    the headroom (1 - density) is the fraction of term budget a random
    block cannot recover, i.e. how early it prunes. 0 when the
    threshold is below the noise floor (nothing can ever prune)."""
    if threshold <= density:
        return 0.0
    return float(min(1.0, max(
        0.0, 1.0 - (1.0 - threshold) / max(1e-6, 1.0 - density))))


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Dispatch decision for one micro-batch."""
    method: str        # 'lookup' | 'vertical' | 'unpack'
    bucket: int        # padded term length (jit-cache shape key)
    batch_size: int    # live queries in the batch
    fused: bool        # True = single pallas_call for the whole batch
    paged: bool = False  # True = dispatch per shard tile, then combine
    n_shards: int = 1
    # tuned kernel knobs (None = kernel defaults; see kernels.autotune)
    word_block: Optional[int] = None
    term_block: Optional[int] = None
    grid_order: str = "wq"
    # minimum batch dedup rate for the row-dedup path (fused lookup plans
    # only); None disables dedup for this plan
    dedup_threshold: Optional[float] = None
    # True = dict-coded shards dispatch through the fused-decode kernels
    # against their compressed (dict, refs) device form; raw shards in the
    # same plan keep the raw path. Chosen by measured lookup-vs-lookup_c
    # cost when the tuner has both, else by the dict-ratio heuristic.
    compressed: bool = False
    # True = the batch runs through the chunked pruned executor
    # (repro.core.query.run_paged_pruned) instead of a whole-query
    # dispatch: terms execute rarest-first in ``chunk_terms``-sized
    # chunks and blocks whose bound can no longer reach the coverage
    # cutoff skip all further tile I/O, staging and kernel work. Taken
    # only when ``predicted_prune`` clears the tuned (or heuristic)
    # break-even rate — the cost model must predict a win.
    pruned: bool = False
    chunk_terms: int = 0
    predicted_prune: float = 0.0


def choose_method(n_hashes: int, bucket: int, batch_size: int,
                  short_query_terms: int = SHORT_QUERY_TERMS,
                  costs: Optional[dict] = None) -> str:
    """The kernel-choice rule, shared by the single-host QueryPlanner and
    the multi-host ShardWorker (both must pick the same kernel for the
    same batch shape so dispatch-mix metrics stay comparable).

    ``costs`` (method -> measured cost, e.g. the tuner's ``cost_us``)
    switches the rule from shape heuristics to measured argmin; methods
    that do not apply to the index (lookup/lookup_c with k>1) are
    ignored. "lookup_c" — the fused-decode kernel over a compressed
    arena — competes on equal footing: it wins only when the measured
    cost WITH the in-kernel decode beats every raw path, i.e. when the
    dict bandwidth saving exceeds the decode cost. Ties break to the
    alphabetically first method, deterministically."""
    if costs:
        ok = {m: c for m, c in costs.items()
              if m not in ("lookup", "lookup_c") or n_hashes == 1}
        if ok:
            return min(sorted(ok), key=ok.get)
    if batch_size > 1:
        # Batched: the fused multi-query kernel whenever it applies (k=1 —
        # the paper's default); otherwise the gather path, with the ADD
        # kernel picked by query length.
        if n_hashes == 1:
            return "lookup"
        return "unpack" if bucket < short_query_terms else "vertical"
    # Singletons: short queries take the cheap expansion; long ones the
    # fused gather (k=1) or vertical counters.
    if bucket < short_query_terms:
        return "unpack"
    return "lookup" if n_hashes == 1 else "vertical"


class QueryPlanner:
    """Chooses the kernel for each (bucket, batch-size) micro-batch and
    owns the memoized score functions for the methods it dispatches, plus
    the per-shard addressing when the arena storage is sharded.

    ``tuner`` wires in measured method costs + tile configs (see module
    docstring); ``word_block`` force-overrides the tile width everywhere
    (ServerConfig surface); ``dedup_min_rate`` sets the fallback dedup
    threshold when no measured break-even exists (None disables the
    dedup path outright); ``compressed`` allows fused-decode dispatch
    against dict-coded shards — taken only when the index HAS such
    shards AND either the tuner's measured lookup_c cost wins the argmin
    or (without measurements) the dict ratio clears
    ``COMPRESSED_MIN_RATIO``."""

    def __init__(self, index: BitSlicedIndex, *,
                 short_query_terms: int = SHORT_QUERY_TERMS,
                 tuner: Optional[KernelTuner] = None,
                 word_block: Optional[int] = None,
                 dedup_min_rate: Optional[float] = DEFAULT_DEDUP_MIN_RATE,
                 compressed: bool = False,
                 pruned: bool = False, prune_chunk: int = 32,
                 prune_min_rate: Optional[float] = None):
        self.index = index
        self.short_query_terms = short_query_terms
        self.tuner = tuner
        self.word_block = word_block
        self.dedup_min_rate = dedup_min_rate
        self.pruned_enabled = bool(pruned)
        self.prune_chunk = int(prune_chunk)
        self.prune_min_rate = (DEFAULT_PRUNE_MIN_RATE
                               if prune_min_rate is None
                               else float(prune_min_rate))
        # Mean slice density for the prune-rate prediction: measured from
        # the store's per-slice popcount stats when the v2 manifest has
        # them, else the configured Bloom FPR (the density every slice
        # targets by construction).
        w = index.storage.shape[1]
        mean_fn = getattr(index.storage, "mean_popcount", None)
        has_fn = getattr(index.storage, "has_popcounts", None)
        if callable(has_fn) and has_fn() and callable(mean_fn) and w:
            self.density = float(mean_fn()) / float(32 * w)
        else:
            self.density = float(index.params.fpr)
        self._k = index.params.n_hashes
        self._single_fns: dict[tuple, object] = {}
        self._batch_fns: dict[tuple, object] = {}
        self._dedup_fns: dict[Optional[int], object] = {}
        self._comp_single_fns: dict[tuple, object] = {}
        self._comp_batch_fns: dict[tuple, object] = {}
        self._comp_dedup_fns: dict[Optional[int], object] = {}
        self.dispatch_counts: Counter[str] = Counter()
        self.n_shards = index.storage.n_shards
        self.shard_plans: list[ShardPlan] = plan_shards(
            index.layout, index.storage.shard_row_starts)
        ratio_fn = getattr(index.storage, "dict_ratio", None)
        self.dict_ratio = ratio_fn() if callable(ratio_fn) else None
        self.compressed_enabled = bool(compressed) and \
            self.dict_ratio is not None

    # -- planning ----------------------------------------------------------
    def plan(self, bucket: int, batch_size: int,
             threshold: Optional[float] = None) -> QueryPlan:
        """Dispatch decision; records nothing. Consults the tuner's
        measured costs when present, falling back to shape heuristics on
        misses (read-only tuners never measure in the serving path).

        ``threshold`` (the batch's weakest coverage threshold) enables
        the pruned-dispatch decision: see ``lookup_pruned``."""
        coverage = threshold
        entries = (self.tuner.costs(bucket, batch_size)
                   if self.tuner is not None else {})
        if not self.compressed_enabled:
            # never dispatch fused-decode when compressed serving is off,
            # even if a tuned lookup_c cost exists in a shared cache
            entries.pop("lookup_c", None)
        costs = {m: e.cost_us for m, e in entries.items()}
        method = choose_method(self._k, bucket, batch_size,
                               self.short_query_terms, costs=costs)
        compressed = method == "lookup_c"
        if compressed:
            method = "lookup"     # lookup_c IS the fused lookup, decoded
            tuned = entries.get("lookup_c")
        else:
            tuned = entries.get(method)
            # no measured comparison for this shape: fall back to the
            # dict-ratio heuristic — decode only when the working set
            # shrinks enough to plausibly pay for the indirection
            if (self.compressed_enabled and method == "lookup"
                    and "lookup_c" not in entries
                    and self.dict_ratio >= COMPRESSED_MIN_RATIO):
                compressed = True
        word_block = (self.word_block if self.word_block is not None
                      else (tuned.word_block if tuned else None))
        term_block = tuned.term_block if tuned else None
        grid_order = tuned.grid_order if tuned else "wq"
        fused = batch_size > 1 and method == "lookup"
        threshold = None
        if fused:
            threshold = (tuned.dedup_threshold
                         if tuned is not None and
                         tuned.dedup_threshold is not None
                         else self.dedup_min_rate)
            if threshold is not None and threshold >= 1.0:
                # unreachable (incl. the tuner's 2.0 "measured, never
                # wins" sentinel): disable outright so the server never
                # pays the per-batch host-side dedup planning
                threshold = None
        plan = QueryPlan(method, bucket, batch_size, fused=fused,
                         paged=self.n_shards > 1, n_shards=self.n_shards,
                         word_block=word_block, term_block=term_block,
                         grid_order=grid_order, dedup_threshold=threshold,
                         compressed=compressed)
        return self.lookup_pruned(plan, coverage) or plan

    def lookup_pruned(self, plan: QueryPlan,
                      coverage: Optional[float]) -> Optional[QueryPlan]:
        """Upgrade ``plan`` to pruned (chunked, early-exit) dispatch when
        the cost model predicts a win, else None.

        ``coverage`` is the batch's weakest coverage threshold (the bound
        every block must clear; None = a top-k-only or unknown batch —
        still pruneable, via the dynamic k-th-score bound, but with no
        basis for a rate prediction we stay unpruned). The break-even
        rate comes from the tuner's measured "lookup_p" entry when one
        exists — its ``dedup_threshold`` field carries the minimum prune
        rate at which the chunked executor beats the best whole-query
        dispatch, with 2.0 meaning "measured, never wins" — else from
        ``prune_min_rate``. The predicted rate comes from
        ``predict_prune_rate`` over the index's measured slice density."""
        if (not self.pruned_enabled or coverage is None
                or plan.bucket <= self.prune_chunk):
            return None
        predicted = predict_prune_rate(float(coverage), self.density)
        break_even = self.prune_min_rate
        chunk = min(self.prune_chunk, plan.bucket)
        word_block = plan.word_block
        if self.tuner is not None:
            e = self.tuner.entry("lookup_p", plan.bucket, plan.batch_size)
            if e is not None:
                if e.dedup_threshold is not None:
                    break_even = e.dedup_threshold
                chunk = min(e.term_block or chunk, plan.bucket)
                if self.word_block is None:
                    word_block = e.word_block
        if break_even >= 1.0 or predicted < break_even:
            return None
        return dataclasses.replace(
            plan, pruned=True, chunk_terms=chunk, word_block=word_block,
            predicted_prune=predicted)

    # -- score-function cache ---------------------------------------------
    def batch_score_fn(self, plan: QueryPlan):
        """score(arena, row_offset, block_width, terms [Q,L,2], n_valid [Q])
        -> [Q, n_slots] for this plan's method + tile config."""
        key = (plan.method, plan.word_block, plan.term_block,
               plan.grid_order)
        fn = self._batch_fns.get(key)
        if fn is None:
            fn = make_batch_score_fn(self._k, plan.method,
                                     word_block=plan.word_block,
                                     term_block=plan.term_block,
                                     grid_order=plan.grid_order)
            self._batch_fns[key] = fn
        return fn

    def dedup_score_fn(self, plan: QueryPlan):
        """score(arena, uniq_rows, indir, mask) -> [Q, n_slots]: the
        row-dedup pair at this plan's tile width."""
        fn = self._dedup_fns.get(plan.word_block)
        if fn is None:
            fn = make_dedup_score_fn(word_block=plan.word_block)
            self._dedup_fns[plan.word_block] = fn
        return fn

    def single_score_fn(self, plan: QueryPlan):
        key = (plan.method, plan.word_block, plan.term_block)
        fn = self._single_fns.get(key)
        if fn is None:
            fn = make_score_fn(self._k, plan.method,
                               word_block=plan.word_block,
                               term_block=plan.term_block)
            self._single_fns[key] = fn
        return fn

    # -- compressed (fused-decode) twins: same keys, (dict, refs) leading
    # arguments instead of the arena. A compressed plan needs BOTH forms —
    # raw shards in a mixed-codec store still take the raw fn.
    def comp_batch_score_fn(self, plan: QueryPlan):
        key = (plan.method, plan.word_block, plan.term_block,
               plan.grid_order)
        fn = self._comp_batch_fns.get(key)
        if fn is None:
            fn = make_comp_batch_score_fn(self._k, plan.method,
                                          word_block=plan.word_block,
                                          term_block=plan.term_block,
                                          grid_order=plan.grid_order)
            self._comp_batch_fns[key] = fn
        return fn

    def comp_dedup_score_fn(self, plan: QueryPlan):
        fn = self._comp_dedup_fns.get(plan.word_block)
        if fn is None:
            fn = make_comp_dedup_score_fn(word_block=plan.word_block)
            self._comp_dedup_fns[plan.word_block] = fn
        return fn

    def comp_single_score_fn(self, plan: QueryPlan):
        key = (plan.method, plan.word_block, plan.term_block)
        fn = self._comp_single_fns.get(key)
        if fn is None:
            fn = make_comp_score_fn(self._k, plan.method,
                                    word_block=plan.word_block,
                                    term_block=plan.term_block)
            self._comp_single_fns[key] = fn
        return fn

    def record(self, plan: QueryPlan, method: Optional[str] = None) -> None:
        """Count a dispatch; ``method`` overrides the plan's label (the
        server reports 'dedup' when the row-dedup path actually ran)."""
        self.dispatch_counts[method or plan.method] += plan.batch_size

    @property
    def methods_used(self) -> tuple[str, ...]:
        return tuple(sorted(self.dispatch_counts))
