"""Elastic scaling of the data axis.

When the healthy-chip count changes (node loss, pool resize), the global
batch must keep its size and ORDER semantics while the per-replica split
changes. ElasticBatchPlan computes a deterministic assignment of global
example indices to replicas for any world size, so scaling from e.g. 32 to
24 data shards mid-run neither drops nor duplicates examples, and the
step-indexed data pipeline stays reproducible (same global batch per step
regardless of topology).

The model/optimizer state is topology-independent (pure pytrees); re-meshing
is a device_put with the new NamedShardings — exercised for the COBS index
in index/distributed.py and for train state in tests/test_ft.py.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ElasticBatchPlan:
    global_batch: int
    world_size: int

    def __post_init__(self):
        if self.global_batch % self.world_size != 0:
            # pad plan: the last replicas take one fewer microbatch row
            pass

    @property
    def per_replica(self) -> int:
        return -(-self.global_batch // self.world_size)   # ceil

    def indices_for(self, replica: int, step: int) -> np.ndarray:
        """Global example indices owned by ``replica`` at ``step``
        (contiguous blocks; tail replicas may get padding index -1)."""
        if not 0 <= replica < self.world_size:
            raise ValueError("bad replica")
        base = step * self.global_batch
        start = replica * self.per_replica
        stop = min(start + self.per_replica, self.global_batch)
        idx = np.arange(start, stop, dtype=np.int64) + base
        pad = self.per_replica - idx.shape[0]
        if pad:
            idx = np.concatenate([idx, np.full(pad, -1, np.int64)])
        return idx

    def coverage_ok(self, step: int = 0) -> bool:
        """Every global index owned exactly once (padding aside)."""
        seen: list[int] = []
        for r in range(self.world_size):
            seen.extend(i for i in self.indices_for(r, step) if i >= 0)
        want = list(range(step * self.global_batch,
                          (step + 1) * self.global_batch))
        return sorted(seen) == want
