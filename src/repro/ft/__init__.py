from .failures import FailureInjector, run_with_restarts
from .elastic import ElasticBatchPlan

__all__ = ["FailureInjector", "run_with_restarts", "ElasticBatchPlan"]
