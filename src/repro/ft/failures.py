"""Failure injection + checkpoint/restart training harness.

``run_with_restarts`` is the supervisor a real launcher wraps around the
training loop: it restores the newest complete checkpoint, runs until a
(possibly injected) failure, and restarts — asserting forward progress.
Deterministic data order across restarts comes from deriving the batch from
the step counter (the framework's data pipeline is step-indexed), so a
killed-and-restarted run reproduces the uninterrupted loss trajectory
bit-for-bit — tested in tests/test_ft.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from ..checkpoint import CheckpointManager


@dataclass
class FailureInjector:
    """Deterministically fail at given global steps (once each)."""
    fail_at: set = field(default_factory=set)
    fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


def run_with_restarts(
    init_state_fn: Callable[[], object],
    step_fn: Callable[[object, int], tuple[object, dict]],
    manager: CheckpointManager,
    total_steps: int,
    checkpoint_every: int = 10,
    injector: FailureInjector | None = None,
    max_restarts: int = 10,
) -> tuple[object, list[dict], int]:
    """Returns (final_state, per-step metrics, restart_count).

    step_fn(state, step) -> (state, metrics). State must be a pytree;
    the supervisor owns checkpoint cadence and crash recovery.
    """
    restarts = 0
    metrics_log: list[dict] = []
    while True:
        # ---- (re)start: restore or init ----
        template = init_state_fn()
        try:
            state, start_step = manager.restore(template)
            start_step += 1
        except FileNotFoundError:
            state, start_step = template, 0
        try:
            for step in range(start_step, total_steps):
                if injector is not None:
                    injector.check(step)
                state, m = step_fn(state, step)
                m = dict(m)
                m["step"] = step
                metrics_log.append(m)
                if (step + 1) % checkpoint_every == 0 or step == total_steps - 1:
                    manager.save(step, state)
            return state, metrics_log, restarts
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            # loop -> restore from newest complete checkpoint
