"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/run1

Single-process reference driver exercising the full stack: config ->
sharded state (rule engine) -> jit'd train_step -> async checkpoints ->
crash-safe resume. On a real cluster the same module runs under
jax.distributed with one process per host; the mesh/sharding/step code is
identical (everything is GSPMD-global).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..checkpoint import AsyncCheckpointer, CheckpointManager
from ..models import build_model
from ..models.partition import partitioning
from ..train import AdamWConfig, make_init_state, make_train_step
from . import sharding as shd
from .mesh import make_mesh


def synthetic_batch(step: int, vocab: int, batch: int, seq: int):
    """Deterministic step-indexed data (replays identically after restart)."""
    rng = np.random.default_rng(step)
    toks = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int64)
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient accumulation steps")
    ap.add_argument("--mesh", default=None,
                    help="e.g. '4,2' => data=4, model=2 (needs devices)")
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    opt = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                      total_steps=args.steps)

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model")[:len(shape)])
    else:
        mesh = make_mesh((len(jax.devices()),), ("data",))

    init = make_init_state(model, opt)
    step_fn = make_train_step(model, opt,
                          microbatches=args.microbatches)
    with mesh, partitioning(mesh, shd.act_rules_for(mesh)):
        _, param_axes = model.abstract_params()
        param_shapes, _ = model.abstract_params()
        param_sh = shd.tree_shardings(param_axes, param_shapes, mesh)
        rep = shd.replicated(mesh)
        state_sh = None  # propagate from params via jit
        jit_init = jax.jit(init)
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

        state = jit_init(jax.random.PRNGKey(0))
        start = 0
        mgr = ckpt = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir)
            ckpt = AsyncCheckpointer(mgr)
            try:
                state, start = mgr.restore(state)
                start += 1
                print(f"resumed from step {start - 1}")
            except FileNotFoundError:
                pass

        t0 = time.time()
        tokens_done = 0
        for step in range(start, args.steps):
            batch = synthetic_batch(step, cfg.vocab, args.batch, args.seq)
            state, metrics = jit_step(state, batch)
            tokens_done += args.batch * args.seq
            if step % 10 == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"acc {float(metrics['accuracy']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"tok/s {tokens_done / max(dt, 1e-9):,.0f}")
            if ckpt and ((step + 1) % args.ckpt_every == 0
                         or step == args.steps - 1):
                ckpt.save(step, state)
        if ckpt:
            ckpt.wait()
        print("done")


if __name__ == "__main__":
    main()
