"""Device mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

Production topology (TPU v5e): one pod = a 16x16 slice = 256 chips, meshed
as (data=16, model=16). Multi-pod adds a leading "pod" axis over DCN:
(pod=2, data=16, model=16) = 512 chips. COBS shards documents over
("pod", "data") and Bloom rows over "model"; the LM substrate shards batch
over ("pod", "data") (FSDP on "data") and tensor/expert dims over "model".
"""
from __future__ import annotations

from jax.sharding import Mesh

from ..compat import make_mesh as _compat_make_mesh


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """jax.make_mesh with explicit Auto axis types where the installed jax
    supports them (keeps the historical shard_map/pjit behaviour stable
    across jax versions)."""
    return _compat_make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The dry-run target: 16x16 single pod, or 2x16x16 across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes carrying the batch/document dimension on this mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: Mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None
