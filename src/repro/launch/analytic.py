"""Analytic FLOP/byte models per (arch x shape).

WHY THIS EXISTS: XLA's HLO cost analysis counts a while-loop body ONCE,
and our layer stacks are lax.scan'd (deliberately — compact HLO is what
makes 48-layer x 512-chip compiles tractable). Raw cost_analysis therefore
undercounts scanned work by the trip count. Verified experimentally:
a scan of 10 matmuls reports exactly 1 matmul of FLOPs.

The roofline compute/memory terms consequently use these analytic models
(the standard MFU methodology); the raw HLO numbers are reported alongside
for cross-checking, and the collective term always comes from the real
partitioned HLO (collectives are NOT inside scan bodies after SPMD
partitioning of the FSDP all-gathers... they are — so the same trip-count
correction is applied to collectives via the per-layer factor, see
collective_corrected()).

Conventions:
  * "computed" FLOPs include causal-mask waste (both the direct and the
    blockwise attention paths compute the full S x T score matrix) — this
    is what the hardware executes;
  * "useful" FLOPs are MODEL_FLOPS = 6 N_active D (train) / 2 N_active D
    (inference) per the assignment spec;
  * matmul = 2 m n k FLOPs; backward = 2x forward; full remat = +1 forward.
"""
from __future__ import annotations

import dataclasses

from ..models.config import LAYERS_PER_KIND, ModelConfig


@dataclasses.dataclass
class FlopsBytes:
    computed_flops: float      # global, what the hardware executes
    useful_flops: float        # global, MODEL_FLOPS
    hbm_bytes: float           # global, estimated HBM traffic


def _attn_proj_flops(cfg: ModelConfig) -> float:
    """qkv + output projection FLOPs per token (forward)."""
    d, hd = cfg.d_model, cfg.head_dim
    return 2 * d * (cfg.n_heads * hd) * 2 + 2 * d * (cfg.n_kv_heads * hd) * 2


def _attn_score_flops(cfg: ModelConfig, s_ctx: int) -> float:
    """score + value einsum FLOPs per token at context length s_ctx."""
    return 2 * 2 * s_ctx * cfg.n_heads * cfg.head_dim


def _mlp_flops(cfg: ModelConfig, d_ff: int, gated: bool) -> float:
    m = 3 if gated else 2
    return 2 * cfg.d_model * d_ff * m


def _per_token_forward(cfg: ModelConfig, S: int, ctx: int | None = None):
    """(matmul flops, attention-quadratic flops) per token, forward pass.
    ctx overrides the attended context length (decode: cache length)."""
    d = cfg.d_model
    mm = 0.0
    qd = 0.0
    for kind, count in cfg.block_pattern:
        kinds = {"griffin": ("rglru", "rglru", "local"),
                 "xunit": ("mlstm", "slstm")}.get(kind, (kind,) * 1)
        if kind not in ("griffin", "xunit"):
            kinds = (kind,)
        for sub in kinds:
            n = count
            if sub in ("attn", "enc", "moe", "xdec"):
                mm += n * _attn_proj_flops(cfg)
                qd += n * _attn_score_flops(cfg, ctx if ctx else S)
                if sub == "xdec":   # cross attention over enc_seq
                    mm += n * _attn_proj_flops(cfg)
                    qd += n * _attn_score_flops(cfg, cfg.enc_seq)
                if sub == "moe":
                    e = cfg.moe
                    mm += n * 2 * d * e.n_experts          # router
                    mm += n * e.top_k * e.capacity_factor * \
                        _mlp_flops(cfg, e.d_ff_expert, True)
                    if e.shared_expert:
                        mm += n * _mlp_flops(cfg, cfg.d_ff, True)
                elif cfg.d_ff:
                    mm += n * _mlp_flops(cfg, cfg.d_ff, cfg.gated_mlp)
            elif sub == "local":
                mm += n * _attn_proj_flops(cfg)
                eff = min(cfg.window, ctx if ctx else S)
                qd += n * _attn_score_flops(cfg, eff)
                if cfg.d_ff:
                    mm += n * _mlp_flops(cfg, cfg.d_ff, cfg.gated_mlp)
            elif sub == "rglru":
                mm += n * (2 * d * d * 5 + 2 * d * 4)      # in/gate/out/a/x
                if cfg.d_ff:
                    mm += n * _mlp_flops(cfg, cfg.d_ff, cfg.gated_mlp)
            elif sub == "mlstm":
                di = 2 * d
                mm += n * (2 * d * 2 * di + 3 * 2 * di * di + 2 * di * d)
                if ctx is None:  # parallel (quadratic) training form
                    qd += n * 2 * 2 * S * di
                else:            # recurrent decode: O(di * dh) state update
                    mm += n * 2 * di * (di // max(cfg.n_heads, 1)) * 2
            elif sub == "slstm":
                dh = d // cfg.n_heads
                mm += n * (2 * d * 4 * d + 2 * 4 * d * dh + 2 * d * d)
    # logits
    mm += 2 * d * cfg.vocab
    return mm, qd


def _encoder_flops(cfg: ModelConfig) -> float:
    """Whisper-style encoder stack FLOPs per SAMPLE (enc_seq frames)."""
    if not cfg.n_enc_layers:
        return 0.0
    per_frame = (_attn_proj_flops(cfg)
                 + _attn_score_flops(cfg, cfg.enc_seq)
                 + (_mlp_flops(cfg, cfg.d_ff, cfg.gated_mlp) if cfg.d_ff
                    else 0.0))
    return cfg.n_enc_layers * per_frame * cfg.enc_seq


def flops_model(cfg: ModelConfig, mode: str, seq_len: int,
                global_batch: int) -> FlopsBytes:
    if mode == "decode":
        n_tokens = global_batch
        mm, qd = _per_token_forward(cfg, 1, ctx=seq_len)
        computed = n_tokens * (mm + qd)     # cross-KV cached: no encoder
        useful = 2.0 * cfg.active_param_count() * n_tokens
    else:
        n_tokens = global_batch * seq_len
        mm, qd = _per_token_forward(cfg, seq_len)
        fwd = n_tokens * (mm + qd) + global_batch * _encoder_flops(cfg)
        if mode == "train":
            remat = 1.0 if cfg.remat == "full" else 0.0
            computed = fwd * (3.0 + remat)
            useful = 6.0 * cfg.active_param_count() * n_tokens
        else:  # prefill
            computed = fwd
            useful = 2.0 * cfg.active_param_count() * n_tokens
    return FlopsBytes(computed, useful, bytes_model(cfg, mode, seq_len,
                                                    global_batch))


def bytes_model(cfg: ModelConfig, mode: str, seq_len: int,
                global_batch: int) -> float:
    """Coarse global HBM-traffic estimate (documented in EXPERIMENTS.md):

    train:  params read twice (fwd+bwd) + grads written + Adam read/write
            (fp32 m, v, p) + activations saved at block boundaries (remat
            'full': one [B,S,d] residual per layer, bf16, written+read).
    decode: params read once + KV-cache/state read+write once.
    prefill:params read once + activations written once + cache written.
    """
    n = cfg.param_count()
    d = cfg.d_model
    L = sum(c * LAYERS_PER_KIND.get(k, 1) for k, c in cfg.block_pattern)
    pbytes = 4  # fp32 master params
    if mode == "decode":
        n_tokens = global_batch
        cache = _cache_bytes(cfg, seq_len, global_batch)
        return n * pbytes + 2 * cache + n_tokens * d * L * 2 * 4
    n_tokens = global_batch * seq_len
    act = n_tokens * d * L * 2 * 2          # bf16 residuals, write+read
    if mode == "train":
        return (2 * n + 1 * n) * pbytes + 6 * n * 4 + 2 * act
    cache = _cache_bytes(cfg, seq_len, global_batch)
    return n * pbytes + act + cache


def _cache_bytes(cfg: ModelConfig, seq_len: int, batch: int) -> float:
    total = 0.0
    for kind, count in cfg.block_pattern:
        kinds = {"griffin": ("rglru", "rglru", "local"),
                 "xunit": ("mlstm", "slstm")}.get(kind, (kind,))
        for sub in kinds:
            if sub in ("attn", "moe", "enc", "xdec"):
                total += count * 2 * batch * seq_len * cfg.n_kv_heads * \
                    cfg.head_dim * 2
            elif sub == "local":
                w = min(cfg.window, seq_len)
                total += count * 2 * batch * w * cfg.n_kv_heads * \
                    cfg.head_dim * 2
            elif sub == "rglru":
                total += count * batch * cfg.d_model * 4 * 4
            elif sub == "mlstm":
                dh = 2 * cfg.d_model // cfg.n_heads
                total += count * batch * cfg.n_heads * (dh * dh + dh) * 4
            elif sub == "slstm":
                total += count * batch * cfg.d_model * 4 * 4
    return total
