"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs_per_chip / peak_FLOPs
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / link_bw

FLOPs/bytes come from compiled.cost_analysis() of the SPMD-partitioned
module (per-device program -> per-chip numbers). Collective bytes are NOT in
cost_analysis: we parse the optimized HLO (compiled.as_text()) and sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, applying ring-transfer factors
(all-reduce 2x; others 1x — the (N-1)/N factor is folded to 1 for N >= 8).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(\([^)]*\)|"
                        r"[a-z]+[0-9]*\[[0-9,]*\]\S*)\s+([\w\-]+)")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)[\s(].*\{")
_BODY_RE = re.compile(r"body=(%[\w\.\-]+)")
_CALL_RE = re.compile(r"to_apply=(%[\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_OPERAND_RE = re.compile(r"\((%[\w\.\-]+)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        if cur is None:
            if not line.startswith(" "):
                m = _COMP_START.match(line)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind transferred bytes (per-chip) from optimized HLO.

    Trip-count-aware: collectives inside while bodies (lax.scan'd layer
    stacks, FSDP gathers) are weighted by the loop's known_trip_count.
    Byte semantics per op (ring algorithms, (N-1)/N ~ 1):
      all-gather: result size | reduce-scatter: operand size |
      all-reduce: 2 x size    | all-to-all / permute: result size.
    """
    comps = _split_computations(hlo_text)

    # first pass: instruction result shapes per computation
    shapes: dict[str, dict[str, str]] = {}
    for cname, lines in comps.items():
        d = {}
        for line in lines:
            m = _ASSIGN_RE.match(line)
            if m:
                d[m.group(1)] = m.group(2)
        shapes[cname] = d

    memo: dict[str, dict[str, float]] = {}

    def walk(cname: str) -> dict[str, float]:
        if cname in memo:
            return memo[cname]
        memo[cname] = {}                       # break recursion cycles
        out: dict[str, float] = {}
        local_shapes = shapes.get(cname, {})
        for line in comps.get(cname, []):
            m = _ASSIGN_RE.match(line)
            if not m:
                continue
            _, result_shape, op = m.groups()
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                if op.endswith("-start") and result_shape.startswith("("):
                    # async tuple (operand, result): use the LARGER element
                    parts = [_shape_bytes(p) for p in
                             result_shape.strip("()").split("), (")]
                    b = max(_shape_bytes(result_shape) // 2,
                            max(parts) if parts else 0)
                else:
                    b = _shape_bytes(result_shape)
                if base == "all-reduce":
                    b *= 2
                    # XLA-CPU promotes bf16 all-reduces to f32 (the operand
                    # is a convert fusion / 'promoted' reducer). TPU reduces
                    # bf16 natively -> count promoted ARs at source width.
                    om = _OPERAND_RE.search(line[line.index(op):])
                    promoted = "promoted" in line
                    if om and "convert" in om.group(1):
                        promoted = True
                    if promoted and result_shape.startswith("f32"):
                        b //= 2
                elif base == "reduce-scatter":
                    om = _OPERAND_RE.search(line[line.index(op):])
                    if om and om.group(1) in local_shapes:
                        b = _shape_bytes(local_shapes[om.group(1)])
                out[base] = out.get(base, 0) + b
            elif op == "while":
                bm = _BODY_RE.search(line)
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                if bm:
                    for k, v in walk(bm.group(1)).items():
                        out[k] = out.get(k, 0) + trip * v
            elif op in ("call", "custom-call", "reduce", "sort", "map",
                        "scatter", "select-and-scatter", "fusion"):
                cm = _CALL_RE.search(line)
                if cm and op == "call":
                    for k, v in walk(cm.group(1)).items():
                        out[k] = out.get(k, 0) + v
            elif op == "conditional":
                bm = _BRANCH_RE.search(line)
                if bm:
                    branches = [b.strip() for b in bm.group(1).split(",")]
                    best: dict[str, float] = {}
                    for b in branches:
                        w = walk(b)
                        if sum(w.values()) > sum(best.values() or [0]):
                            best = w
                    for k, v in best.items():
                        out[k] = out.get(k, 0) + v
        memo[cname] = out
        return out

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_START.match(line)
            if m:
                entry = m.group(1)
                break
    if entry is None:
        return {}
    return {k: int(v) for k, v in walk(entry).items()}


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float             # analytic computed FLOPs / chips
    bytes_per_chip: float             # analytic HBM traffic / chips
    coll_bytes_per_chip: float        # trip-corrected HLO collectives
    coll_breakdown: dict
    model_flops: float = 0.0          # 6*N*D (or 2*N_active*D) global
    chips: int = 1
    hlo_flops_raw: float = 0.0        # cost_analysis (scan bodies once!)
    hlo_bytes_raw: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops): remat/redundancy waste."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "chips": self.chips,
            "hlo_flops_raw": self.hlo_flops_raw,
            "hlo_bytes_raw": self.hlo_bytes_raw,
        }


def model_flops_for(cfg, shape, n_tokens: int) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for inference (forward only);
    MoE uses active params."""
    n = cfg.active_param_count()
    factor = 6.0 if shape.mode == "train" else 2.0
    return factor * n * n_tokens


def analyze(compiled, cfg, shape, chips: int) -> Roofline:
    from . import analytic

    cost = {}
    try:
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, list):            # some backends return [dict]
            cost = cost[0] if cost else {}
    except Exception:
        pass
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)
    fb = analytic.flops_model(cfg, shape.mode, shape.seq_len,
                              shape.global_batch)
    return Roofline(
        flops_per_chip=fb.computed_flops / chips,
        bytes_per_chip=fb.hbm_bytes / chips,
        coll_bytes_per_chip=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=fb.useful_flops,
        chips=chips,
        hlo_flops_raw=float(cost.get("flops", 0.0)),
        hlo_bytes_raw=float(cost.get("bytes accessed", 0.0)),
    )


def memory_analysis_dict(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception as e:                       # pragma: no cover
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(m)
    return out
