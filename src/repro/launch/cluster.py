"""Multi-process worker-cluster launcher for the RPC data plane.

Spawns N ``repro.launch.serve --worker`` subprocesses over one shared v2
store, waits for their ``--port-file`` publications, and hands back the
``{node: (host, port)}`` map a ``WorkerPool`` / ``--workers`` frontend
dials. Used by tests (tests/test_rpc_plane.py) and benchmarks
(benchmarks/serving.py --rpc); also handy interactively:

    from repro.launch.cluster import WorkerCluster
    with WorkerCluster(store_dir, ["host0", "host1", "host2"]) as cl:
        pool = WorkerPool(cl.addresses)
        ...
        cl.kill("host1")            # SIGKILL mid-load, shards fail over
        cl.restart("host1")         # same port: channels backoff-redial

Fault injection is first-class: ``kill`` SIGKILLs a worker without
cleanup (torn frames, dead peer), ``restart`` relaunches it on the SAME
port so the frontend's reconnecting channels find it again.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import tempfile
import time
from typing import Optional


def _repo_src_dir() -> str:
    """The directory to put on the child's PYTHONPATH so ``import
    repro`` resolves to the same tree as the parent."""
    import repro
    return os.path.dirname(os.path.dirname(os.path.abspath(
        repro.__file__)))


def wait_port_file(path: str, proc: Optional[subprocess.Popen] = None,
                   timeout_s: float = 60.0) -> tuple[str, int]:
    """Poll for a worker's atomic 'host port' publication; fail fast
    with the child's output if it died instead of binding."""
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        try:
            with open(path) as f:
                parts = f.read().split()
            if len(parts) == 2:
                return parts[0], int(parts[1])
        except (FileNotFoundError, ValueError):
            pass
        if proc is not None and proc.poll() is not None:
            out = ""
            if proc.stdout is not None:
                out = proc.stdout.read().decode("utf-8", "replace")
            raise RuntimeError(
                f"worker exited rc={proc.returncode} before publishing "
                f"{path}:\n{out[-2000:]}")
        time.sleep(0.05)
    raise TimeoutError(f"no port file at {path} after {timeout_s:.0f}s")


class WorkerCluster:
    """N worker subprocesses over one v2 store; context manager."""

    def __init__(self, store_dir: str, nodes: list[str], *,
                 replication: int = 2, straggle_ms: dict | float = 0.0,
                 pruned: bool = False, run_dir: Optional[str] = None,
                 spawn_timeout_s: float = 60.0):
        self.store_dir = str(store_dir)
        self.nodes = list(nodes)
        self.replication = replication
        self.pruned = pruned
        self.spawn_timeout_s = spawn_timeout_s
        # per-node straggler injection: a float applies to every node
        self.straggle_ms = (dict(straggle_ms)
                            if isinstance(straggle_ms, dict)
                            else {n: straggle_ms for n in nodes})
        self.run_dir = run_dir or tempfile.mkdtemp(prefix="rpc-cluster-")
        self.procs: dict[str, subprocess.Popen] = {}
        self.addresses: dict[str, tuple[str, int]] = {}

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "WorkerCluster":
        for node in self.nodes:
            self._spawn(node, port=0)
        for node in self.nodes:
            self.addresses[node] = wait_port_file(
                self._port_file(node), self.procs[node],
                self.spawn_timeout_s)
        return self

    def _port_file(self, node: str) -> str:
        return os.path.join(self.run_dir, f"{node}.port")

    def _spawn(self, node: str, port: int) -> None:
        pf = self._port_file(node)
        try:
            os.remove(pf)
        except FileNotFoundError:
            pass
        cmd = [sys.executable, "-m", "repro.launch.serve",
               "--store-format", "v2", "--index-dir", self.store_dir,
               "--worker", node, "--worker-nodes", ",".join(self.nodes),
               "--replication", str(self.replication),
               "--worker-port", str(port), "--port-file", pf]
        if self.straggle_ms.get(node):
            cmd += ["--straggle-ms", str(self.straggle_ms[node])]
        if self.pruned:
            cmd += ["--prune"]
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (_repo_src_dir(), env.get("PYTHONPATH")) if p)
        # workers only score small CPU batches; keep child JAX off any
        # accelerator the parent may be using
        env.setdefault("JAX_PLATFORMS", "cpu")
        self.procs[node] = subprocess.Popen(
            cmd, env=env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT,
            start_new_session=True)     # isolate from parent's Ctrl-C

    # -- fault injection -----------------------------------------------------
    def kill(self, node: str, sig: int = signal.SIGKILL) -> None:
        """Hard-kill one worker (no drain, no FIN ordering guarantees
        beyond the OS closing the sockets) — the dead-peer case."""
        proc = self.procs[node]
        if proc.poll() is None:
            proc.send_signal(sig)
            proc.wait(timeout=10)

    def restart(self, node: str) -> tuple[str, int]:
        """Relaunch a killed worker on the SAME port, so the frontend's
        reconnecting channels (which redial host:port) recover it."""
        self.kill(node)                 # idempotent if already dead
        host, port = self.addresses[node]
        self._spawn(node, port=port)
        self.addresses[node] = wait_port_file(
            self._port_file(node), self.procs[node], self.spawn_timeout_s)
        return self.addresses[node]

    def output(self, node: str) -> str:
        """Captured stdout+stderr of a FINISHED worker ('' if alive)."""
        proc = self.procs[node]
        if proc.poll() is None or proc.stdout is None:
            return ""
        return proc.stdout.read().decode("utf-8", "replace")

    def close(self) -> None:
        for node, proc in self.procs.items():
            if proc.poll() is None:
                proc.send_signal(signal.SIGKILL)
        for proc in self.procs.values():
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                pass
            if proc.stdout is not None:
                proc.stdout.close()

    def __enter__(self) -> "WorkerCluster":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
