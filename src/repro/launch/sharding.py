"""Divisibility-aware sharding rule engine.

Ten heterogeneous architectures cannot share one hard-coded PartitionSpec
table: 10/24/40 query heads, 1–20 KV heads, 16–128 experts and 49k–256k
vocabularies all divide a 16-way model axis differently. Instead every
parameter/cache dimension carries a LOGICAL name (assigned at init in
models/*) and this engine resolves names -> mesh axes per tensor:

  * candidates are tried in order (e.g. attention: "heads" first, then the
    "head_dim" fallback — that is how recurrentgemma's 10 heads still get
    tensor-parallel attention);
  * a candidate is accepted only if the dim size divides the mesh axes'
    product and no mesh axis is reused within the tensor;
  * "embed" -> "data" gives ZeRO-3/FSDP parameter sharding on top of TP,
    which is what makes 17B-a16e (1TB of fp32 param+Adam state) fit
    16 GB/chip.

The same engine produces activation-hint rules for models.partition.hint.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# logical axis -> ordered candidate mesh-axis tuples.
#
# NOTE on head_dim: sharding q/k/v over head_dim looks tempting as a TP
# fallback when the head counts don't divide the model axis, but head_dim is
# the CONTRACTING dim of the score einsum — XLA then all-reduces the S x T
# score matrix every layer (measured 43 s/step of collective time on
# phi4 x train_4k in the dry-run). Training/prefill therefore REPLICATES
# attention over "model" when heads don't divide (visible as compute-term
# inflation, attacked in §Perf); decode CACHES keep the head_dim fallback —
# there the psum is tiny ([B,1,T] scores) and the 16x cache-memory saving is
# what makes decode_32k fit 16 GB/chip.
PARAM_RULES: dict[str, list[tuple[str, ...]]] = {
    "vocab": [("model",)],
    "ff": [("model",)],
    "experts": [("model",)],
    "heads": [("model",)],
    "kv": [("model",)],
    "rec": [("model",)],
    "embed": [("data",)],           # FSDP / ZeRO-3
    "batch": [("pod", "data")],
    "head_dim": [],
    "kv_seq": [],
    "seq": [],
    "layers": [],
    "enc_seq": [],
}

CACHE_RULES: dict[str, list[tuple[str, ...]]] = {
    **PARAM_RULES,
    "kv": [("model",)],
    "head_dim": [("model",)],       # fallback: shard cache over head_dim
}

# activation constraint rules (models.partition.hint): single candidate each
ACT_RULES: dict[str, tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "experts": ("model",),
    "ff": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "vocab": ("model",),
    "rec": ("model",),
    "embed": None,
    "seq": None,
}


def _filter_axes(cand: tuple[str, ...], mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in cand if a in mesh.axis_names)


def spec_for(axes: tuple[str | None, ...], shape: tuple[int, ...],
             mesh: Mesh, rules: dict | None = None) -> PartitionSpec:
    """Resolve one tensor's logical axes to a PartitionSpec."""
    rules = rules if rules is not None else PARAM_RULES
    used: set[str] = set()
    parts: list = []
    for i, name in enumerate(axes):
        assigned = None
        for cand in rules.get(name, []) if name else []:
            cand = _filter_axes(cand, mesh)
            if not cand or any(a in used for a in cand):
                continue
            size = 1
            for a in cand:
                size *= mesh.shape[a]
            if size > 1 and shape[i] % size == 0:
                assigned = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        parts.append(assigned)
    while parts and parts[-1] is None:
        parts.pop()
    return PartitionSpec(*parts)


def tree_specs(axes_tree, shape_tree, mesh: Mesh, rules: dict | None = None):
    """Parallel (axes, shapes) pytrees -> PartitionSpec pytree."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    return jax.tree.map(
        lambda a, s: spec_for(a, tuple(s.shape), mesh, rules),
        axes_tree, shape_tree, is_leaf=is_axes)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh,
                   rules: dict | None = None):
    specs = tree_specs(axes_tree, shape_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


def act_rules_for(mesh: Mesh) -> dict:
    """hint() rules filtered to this mesh's axes."""
    out = {}
    for name, cand in ACT_RULES.items():
        if cand is None:
            out[name] = None
        else:
            f = _filter_axes(cand, mesh)
            out[name] = f if f else None
    return out


def batch_sharding(mesh: Mesh, batch_size: int) -> NamedSharding:
    """Sharding for [B, ...] data tensors; falls back to replication when
    the batch doesn't divide (e.g. long_500k's B=1)."""
    cand = _filter_axes(("pod", "data"), mesh)
    size = 1
    for a in cand:
        size *= mesh.shape[a]
    if cand and batch_size % size == 0:
        return NamedSharding(mesh, PartitionSpec(cand if len(cand) > 1
                                                 else cand[0]))
    return replicated(mesh)
