"""Input specifications for every (architecture x shape) dry-run cell.

ShapeDtypeStruct stand-ins only — weak-type-correct, shardable, zero device
allocation. Each cell bundles: the step function to lower (train_step /
prefill_step / decode_step), its abstract arguments, and in_shardings
resolved by the rule engine for the given mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .. import configs
from ..models import build_model
from ..models.config import ModelConfig
from ..models.transformer import Model
from ..serve import make_decode_step, make_prefill_step
from ..train import AdamWConfig, make_init_state, make_train_step
from . import sharding as shd


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str                     # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(supported, reason-if-not). long_500k needs sub-quadratic attention;
    decode shapes need a decoder."""
    s = SHAPES[shape_name]
    if s.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k skipped (DESIGN.md)"
    if s.mode == "decode" and not cfg.has_decoder:
        return False, "encoder-only arch: no decode step"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _smoke_scale(s: ShapeSpec) -> ShapeSpec:
    """Reduced copy of a shape for CPU smoke compiles."""
    return ShapeSpec(s.name, min(s.seq_len, 64), min(s.global_batch, 8),
                     s.mode)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    cfg: ModelConfig
    model: Model
    step_fn: Callable
    args: tuple                    # abstract args (ShapeDtypeStructs)
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


def _batch_specs(cfg: ModelConfig, mesh: Mesh, B: int, S: int):
    bsh = shd.batch_sharding(mesh, B)
    batch = {"tokens": _sds((B, S), jnp.int32),
             "labels": _sds((B, S), jnp.int32)}
    shard = {"tokens": bsh, "labels": bsh}
    if cfg.n_enc_layers:
        batch["enc_feats"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.float32)
        shard["enc_feats"] = bsh
    return batch, shard


def make_cell(arch: str, shape_name: str, mesh: Mesh,
              smoke: bool = False) -> Cell:
    cfg = configs.get(arch, smoke=smoke)
    s = SHAPES[shape_name]
    if smoke:
        s = _smoke_scale(s)
    ok, why = cell_supported(cfg, shape_name)
    if not ok:
        raise ValueError(f"{arch} x {shape_name}: {why}")
    model = build_model(cfg)
    _, param_axes = model.abstract_params()
    param_shapes, _ = model.abstract_params()
    param_sh = shd.tree_shardings(param_axes, param_shapes, mesh)
    rep = shd.replicated(mesh)

    B, S = s.global_batch, s.seq_len

    if s.mode == "train":
        opt = AdamWConfig()
        state_shape = jax.eval_shape(make_init_state(model, opt),
                                     _sds((2,), jnp.uint32))
        state_sh = state_shape._replace(
            step=rep, params=param_sh,
            opt_state={"mu": param_sh, "nu": param_sh, "count": rep},
            rng=rep)
        batch, batch_sh = _batch_specs(cfg, mesh, B, S)
        step = make_train_step(model, opt)
        return Cell(arch, s, cfg, model, step,
                    (state_shape, batch), (state_sh, batch_sh),
                    (state_sh, None), donate_argnums=(0,))

    if s.mode == "prefill":
        batch, batch_sh = _batch_specs(cfg, mesh, B, S)
        batch.pop("labels")
        batch_sh.pop("labels")
        step = make_prefill_step(model, cache_len=S)
        cache_sh = _cache_shardings(model, mesh, B, S)
        return Cell(arch, s, cfg, model, step,
                    (param_shapes, batch), (param_sh, batch_sh),
                    (None, cache_sh))

    # decode: one new token against a seq_len cache
    cache_shape = jax.eval_shape(lambda: model.init_cache(B, S))
    cache_sh = _cache_shardings(model, mesh, B, S)
    tokens = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    bsh = shd.batch_sharding(mesh, B)
    step = make_decode_step(model)
    return Cell(arch, s, cfg, model, step,
                (param_shapes, cache_shape, tokens, pos),
                (param_sh, cache_sh, bsh, rep),
                (None, cache_sh), donate_argnums=(1,))


def _cache_shardings(model: Model, mesh: Mesh, B: int, S: int):
    cache_shape = jax.eval_shape(lambda: model.init_cache(B, S))
    cache_axes = model.cache_axes()
    return shd.tree_shardings(cache_axes, cache_shape, mesh,
                              rules=shd.CACHE_RULES)
