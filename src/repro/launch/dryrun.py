import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# init. The placeholder devices exist ONLY in this process, ONLY for the
# dry-run; tests and benchmarks see the real single device.

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro import configs
from repro.launch import analysis, sharding as shd
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.specs import SHAPES, cell_supported, make_cell
from repro.models.partition import partitioning

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell and each production mesh
(single-pod 16x16 = 256 chips; multi-pod 2x16x16 = 512 chips):

    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...) \
            .lower(*input_specs(arch, shape))
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the system. Results stream to a JSONL file consumed by
EXPERIMENTS.md §Dry-run and benchmarks/roofline.py.

Also runs the COBS index cells: the sharded signature-index query step
lowered on the same meshes (documents over ("pod","data"), Bloom rows over
"model") — the paper's workload on the production topology.
"""


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             smoke: bool = False) -> dict:
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": mesh.devices.size}
    cfg = configs.get(arch, smoke=smoke)
    ok, why = cell_supported(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    t0 = time.time()
    try:
        cell = make_cell(arch, shape_name, mesh, smoke=smoke)
        with mesh, partitioning(mesh, shd.act_rules_for(mesh)):
            jitted = jax.jit(cell.step_fn,
                             in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings,
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = analysis.memory_analysis_dict(compiled)
        roof = analysis.analyze(compiled, cell.cfg, cell.shape,
                                chips=mesh.devices.size)
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), memory=mem,
                   roofline=roof.as_dict(),
                   params=cell.cfg.param_count(),
                   active_params=cell.cfg.active_param_count())
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def run_cobs_cell(mesh, mesh_name: str, n_docs: int = 102_400,
                  n_terms_avg: int = 3_400_000, batch_queries: int = 64,
                  ell: int = 1024, score_method: str = "vertical",
                  score_dtype=None) -> dict:
    """Lower the sharded COBS query step at paper scale (100k documents,
    3.4M avg 31-mers) without allocating the index: the arena is a
    ShapeDtypeStruct, documents shard over ("pod","data"), rows over
    "model"."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core import theory
    from repro.core.index import BitSlicedIndex, IndexParams
    from repro.index.distributed import DistributedIndex

    rec = {"arch": "cobs-index", "shape": f"query_b{batch_queries}",
           "mesh": mesh_name, "chips": mesh.devices.size}
    t0 = time.time()
    try:
        block_docs = 1024
        n_blocks = n_docs // block_docs
        w = theory.bloom_size(n_terms_avg, 0.3, 1)
        w = (w + 511) // 512 * 512
        # abstract index: arena rows = n_blocks * w (uniform-avg staircase)
        idx = BitSlicedIndex(
            arena=jax.ShapeDtypeStruct((n_blocks * w, block_docs // 32),
                                       jnp.uint32),
            row_offset=jnp.arange(n_blocks, dtype=jnp.int32) * w,
            block_width=jnp.full((n_blocks,), w, jnp.int32),
            doc_slot=jnp.arange(0, dtype=jnp.int32),      # unused in lowering
            doc_n_terms=jnp.arange(0, dtype=jnp.int32),
            block_docs=block_docs, n_docs=n_docs,
            params=IndexParams(),
        )
        # build the sharded engine WITHOUT device_put (abstract arena)
        dist = DistributedIndex.__new__(DistributedIndex)
        dist.mesh = mesh
        dist.doc_axes = tuple(a for a in ("pod", "data")
                              if a in mesh.axis_names)
        dist.row_axis = "model"
        dist.params = idx.params
        dist.score_method = score_method
        dist.score_dtype = score_dtype or jnp.int32
        dist.n_docs = n_docs
        import math as _m
        n_doc_shards = _m.prod(mesh.shape[a] for a in dist.doc_axes)
        n_row_shards = mesh.shape["model"]
        rows_padded = (idx.arena.shape[0] + n_row_shards - 1) \
            // n_row_shards * n_row_shards
        words_padded = (idx.arena.shape[1] + n_doc_shards - 1) \
            // n_doc_shards * n_doc_shards
        dist.doc_words = words_padded
        dist.total_rows = rows_padded
        dist.row_stripe = rows_padded // n_row_shards
        dist.words_local = words_padded // n_doc_shards
        dist.n_blocks = n_blocks
        dist.slots_per_block = words_padded * 32
        dist._score_jit = None
        dist._topk_jit = {}

        from jax.sharding import NamedSharding, PartitionSpec as P
        doc = dist.doc_axes if len(dist.doc_axes) > 1 else dist.doc_axes[0]
        arena_sds = jax.ShapeDtypeStruct((rows_padded, words_padded),
                                         jnp.uint32)
        body = dist._shard_body(topk=32)
        in_specs, out_specs = dist._specs(32)
        from ..compat import shard_map
        fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
        terms = jax.ShapeDtypeStruct((batch_queries, ell, 2), jnp.uint32)
        nval = jax.ShapeDtypeStruct((batch_queries,), jnp.int32)
        with mesh:
            jitted = jax.jit(
                fn,
                in_shardings=(NamedSharding(mesh, P("model", doc)),
                              NamedSharding(mesh, P()), NamedSharding(mesh, P()),
                              NamedSharding(mesh, P()), NamedSharding(mesh, P())))
            lowered = jitted.lower(
                arena_sds,
                jax.ShapeDtypeStruct((n_blocks,), jnp.int32),
                jax.ShapeDtypeStruct((n_blocks,), jnp.int32),
                terms, nval)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = analysis.memory_analysis_dict(compiled)
        cost = {}
        try:
            cost = compiled.cost_analysis() or {}
            if isinstance(cost, list):
                cost = cost[0]
        except Exception:
            pass
        coll = analysis.collective_bytes(compiled.as_text())
        index_bytes = rows_padded * words_padded * 4
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), memory=mem,
                   index_bytes_total=index_bytes,
                   index_bytes_per_chip=index_bytes // mesh.devices.size,
                   flops_per_chip=float(cost.get("flops", 0.0)),
                   bytes_per_chip=float(cost.get("bytes accessed", 0.0)),
                   coll_breakdown=coll,
                   coll_bytes_per_chip=float(sum(coll.values())))
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all' or 'cobs'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs/shapes (CI)")
    ap.add_argument("--out", default=None, help="JSONL output path")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single-pod-16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi-pod-2x16x16",
                       make_production_mesh(multi_pod=True)))

    archs = configs.list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    out_path = Path(args.out) if args.out else None
    if out_path:
        out_path.parent.mkdir(parents=True, exist_ok=True)

    failures = 0
    records = []
    for mesh_name, mesh in meshes:
        if args.arch in ("all", "cobs"):
            rec = run_cobs_cell(mesh, mesh_name)
            records.append(rec)
            _emit(rec, out_path)
            failures += rec["status"] == "error"
        if args.arch == "cobs":
            continue
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh, mesh_name,
                               smoke=args.smoke)
                records.append(rec)
                _emit(rec, out_path)
                failures += rec["status"] == "error"

    ok = sum(r["status"] == "ok" for r in records)
    sk = sum(r["status"] == "skipped" for r in records)
    print(f"\n== dry-run done: {ok} ok, {sk} skipped, {failures} errors ==")
    return 1 if failures else 0


def _emit(rec: dict, out_path: Path | None) -> None:
    status = rec["status"]
    extra = ""
    if status == "ok" and "roofline" in rec:
        r = rec["roofline"]
        extra = (f" t_comp={r['t_compute_s']:.3e}s t_mem={r['t_memory_s']:.3e}s"
                 f" t_coll={r['t_collective_s']:.3e}s -> {r['bottleneck']}")
    elif status == "ok":
        extra = f" index/chip={rec.get('index_bytes_per_chip', 0)/2**30:.2f}GiB"
    elif status == "error":
        extra = " " + rec.get("error", "")
    elif status == "skipped":
        extra = " " + rec.get("reason", "")
    print(f"[{rec['mesh']}] {rec['arch']} x {rec['shape']}: {status}{extra}",
          flush=True)
    if out_path:
        with out_path.open("a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    sys.exit(main())
