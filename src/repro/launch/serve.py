"""Index-serving launcher: drive the repro.serve query-serving subsystem
(micro-batcher + planner + caches) under generated load and report
latency/throughput.

    PYTHONPATH=src python -m repro.launch.serve --n-docs 256 --queries 200
    PYTHONPATH=src python -m repro.launch.serve --mode open --qps 500
    PYTHONPATH=src python -m repro.launch.serve --store-format v2 \\
        --index-dir /tmp/store --hosts 3 --replication 2 --fail-host host1
    PYTHONPATH=src python -m repro.launch.serve --store-format v2 \\
        --index-dir /tmp/store --autotune      # tune-then-serve; measured
                                               # configs persist in
                                               # /tmp/store/tuning.json
    PYTHONPATH=src python -m repro.launch.serve --listen 7070
                                               # network mode: TCP wire
                                               # protocol, active loop

``--listen PORT`` swaps load generation for real serving: the chosen
backend (QueryServer, or the sharded Frontend with --hosts) is wrapped
in a ServingLoop (dispatcher + scoring workers) behind the binary wire
protocol — concurrent clients coalesce into shared micro-batches, queue
overflow answers 429-style REJECTED, Ctrl-C drains and exits. Query it
with ``repro.serve.NetClient`` or ``benchmarks/serving.py --listen``.
A ``BulkLane`` is attached to the loop, so clients can submit whole
query sets over the wire (``NetClient.bulk`` / the BULK frame); they
sweep shard-major in interactive idle time.

``--bulk FILE`` submits the patterns in FILE (one per line) through the
offline bulk lane: in --listen mode the job runs alongside network
traffic, otherwise it runs inline after the load-generation report —
either way the summary prints arena bytes staged per query, the bulk
lane's headline number. ``--bulk-checkpoint PATH`` makes every finished
shard resumable across runs.

Two load models:

* ``closed`` — a fixed window of in-flight queries: submit ``--concurrency``
  at a time, drain, repeat. Measures the system's capacity (best-case
  batching).
* ``open``   — Poisson arrivals at ``--qps`` on the wall clock: submit at
  each arrival instant, ``step`` the server in between so flush timers
  fire. Measures latency under a fixed offered load, queueing included.

``--hosts N`` switches from the single-host QueryServer to the sharded
data plane: the v2 store's manifest rows are HRW-placed over N in-process
fake hosts (``--replication`` replicas each), every host opens a sub-store
of only its shards (a ShardWorker), and a Frontend scatters micro-batches
with hedged dispatch (``--hedge-after-ms``) and gathers the final top-k.
``--fail-host`` marks hosts down before the measured run to demo replica
failover.

Real multi-PROCESS serving (PR 10) splits those fake hosts into process
roles over the v4 wire protocol:

* ``--worker NAME`` — run this process as ONE ShardWorker behind its own
  WorkerServer. The logical node list (``--worker-nodes n0,n1,n2``) plus
  the store manifest determine the HRW placement deterministically, so
  every process computes the same shard->node map without coordination;
  ``--worker-port`` picks the bind port (0 = OS-assigned) and
  ``--port-file PATH`` atomically publishes "host port" once bound —
  the launcher/tests discover OS-assigned ports from it.
  ``--straggle-ms`` injects a per-dispatch straggler tail (cancellation-
  aware) for hedging demos/benches.
* ``--workers n0=host:port,n1=@portfile,...`` — run this process as the
  frontend: dial every worker through the reconnecting channel pool
  (``repro.serve.rpc.WorkerPool``) and scatter every shard dispatch as a
  real RPC with wall-clock hedging and CANCEL-on-win. Combine with
  ``--listen`` for the TCP front door, or without it to drive the
  generated load through the RPC plane.

    # terminal 1..3: three workers on localhost (OS-assigned ports)
    python -m repro.launch.serve --store-format v2 --index-dir /tmp/store \\
        --worker host0 --worker-nodes host0,host1,host2 \\
        --port-file /tmp/w0.port          # likewise host1, host2
    # terminal 4: the frontend, dialing the port files
    python -m repro.launch.serve --store-format v2 --index-dir /tmp/store \\
        --workers host0=@/tmp/w0.port,host1=@/tmp/w1.port,host2=@/tmp/w2.port \\
        --listen 7070

Results are validated against the ground-truth origin labels of the
synthetic query set, and the report includes the planner's kernel mix and
cache hit rate alongside p50/p99 (plus per-worker latency, hedge-fire
rate, and failover counts in multi-host mode).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from ..core import IndexParams, build_compact, load_index, save_index
from ..data import make_corpus, make_queries
from ..serve import (Frontend, FrontendConfig, QueryServer, ServerConfig,
                     ShardWorker, Status)


def build_or_load(args):
    corpus = make_corpus(args.n_docs, k=15, mean_length=2000, sigma=1.0,
                         seed=0)
    params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
    index = None
    if args.index_dir:
        try:
            index = load_index(args.index_dir)
            print(f"loaded index from {args.index_dir} "
                  f"({index.storage.n_shards} shard(s))")
        except FileNotFoundError:
            pass
    if index is None:
        t0 = time.time()
        if args.store_format == "v2" and args.index_dir:
            # out-of-core path: stream shards to disk, serve via mmap
            from ..index import build_compact_streaming
            index, stats = build_compact_streaming(
                corpus.doc_terms, args.index_dir, params, block_docs=64)
            print(f"streamed v2 store: {index.n_docs} docs, "
                  f"{stats.n_shards} shards, peak build host "
                  f"{stats.peak_block_bytes / 2**20:.2f} MiB "
                  f"in {time.time()-t0:.1f}s")
        else:
            # (store_format is necessarily v1 here: v2 + index_dir took the
            # streaming branch, and v2 without index_dir errors at parse)
            index = build_compact(corpus.doc_terms, params, block_docs=64)
            print(f"built compact index: {index.n_docs} docs, "
                  f"{index.size_bytes() / 2**20:.1f} MiB "
                  f"in {time.time()-t0:.1f}s")
            if args.index_dir:
                save_index(index, args.index_dir)
    return corpus, index


def make_workload(corpus, n_queries: int, seed: int = 100):
    """Mixed-length query stream of EXACTLY n_queries (short queries
    exercise the planner's unpack path, long ones the fused/vertical
    paths)."""
    queries, origin = [], []
    lengths = (40, 80, 160, 320)
    for i, length in enumerate(lengths):
        count = n_queries // len(lengths) + (i < n_queries % len(lengths))
        if count == 0:
            continue
        q, o = make_queries(corpus, n_pos=count - count // 2,
                            n_neg=count // 2, length=length,
                            seed=seed + i)
        queries.extend(q)
        origin.extend(o)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(queries))
    return [queries[i] for i in perm], [origin[i] for i in perm]


def run_closed(server: QueryServer, queries, threshold: float,
               concurrency: int) -> list[int]:
    ids = []
    for i in range(0, len(queries), concurrency):
        for q in queries[i: i + concurrency]:
            ids.append(server.submit(q, threshold=threshold))
        server.drain()
    return ids


def run_open(server: QueryServer, queries, threshold: float, qps: float
             ) -> list[int]:
    rng = np.random.default_rng(0)
    gaps = rng.exponential(1.0 / qps, size=len(queries))
    arrival = server.clock() + np.cumsum(gaps)
    ids = []
    for q, t_arr in zip(queries, arrival):
        while server.clock() < t_arr:
            server.step()                     # let flush timers fire
            remaining = t_arr - server.clock()
            if remaining > 0:
                time.sleep(min(remaining, 1e-4))
        ids.append(server.submit(q, threshold=threshold))
        server.step()
    server.drain()
    return ids


def make_multihost_frontend(store_dir, *, hosts: int, replication: int,
                            max_batch: int, max_wait_s: float,
                            hedge_after_s: float, hedge_auto: bool = False,
                            tile_cache_bytes=None, word_block=None,
                            scatter_threads: int = 4,
                            fail_hosts=(), latency_models=None,
                            tracing: bool = True,
                            trace_slow_ms: float = 0.0,
                            trace_log=None, pruned: bool = False,
                            prune_chunk: int = 32,
                            prune_min_rate=None,
                            adaptive_buckets: bool = False) -> Frontend:
    """Sharded data plane over in-process fake hosts: HRW-place the v2
    manifest rows, open each host's sub-store, wire the hedging frontend
    (per-shard dispatches overlap through ``scatter_threads`` in
    wall-clock mode), and optionally mark hosts down (their shards fail
    over to replicas)."""
    from ..index import ShardPlacement

    nodes = [f"host{i}" for i in range(hosts)]
    placement = ShardPlacement.for_store(store_dir, nodes,
                                         replication=min(replication, hosts))
    held = placement.replica_assignment()
    workers = {n: ShardWorker(n, store_dir, held[n],
                              tile_cache_bytes=tile_cache_bytes,
                              word_block=word_block, pruned=pruned,
                              prune_chunk=prune_chunk,
                              prune_min_rate=prune_min_rate)
               for n in nodes if held[n]}
    frontend = Frontend(workers, placement, FrontendConfig(
        max_batch=max_batch, max_wait_s=max_wait_s,
        hedge_after_s=hedge_after_s, hedge_auto=hedge_auto,
        scatter_threads=scatter_threads, tracing=tracing,
        trace_slow_ms=trace_slow_ms, trace_log=trace_log,
        pruned=pruned, prune_chunk=prune_chunk,
        adaptive_buckets=adaptive_buckets),
        latency_models=latency_models)
    for n in fail_hosts:
        frontend.fail_worker(n)
    if not placement.is_covered():
        raise SystemExit("placement lost coverage: too many failed hosts "
                         "for the replication factor")
    return frontend


def run_worker(args) -> None:
    """Process role: serve ONE placement node's shard replicas over the
    v4 wire protocol until interrupted (see module docstring). The node
    list + store manifest pin the HRW placement, so this process opens
    exactly the shards the frontend will route to it — no coordination
    beyond agreeing on ``--worker-nodes`` and ``--replication``."""
    from ..index import ShardPlacement
    from ..serve.net import PROTO_VERSION
    from ..serve.rpc import WorkerServer

    if not os.path.exists(os.path.join(args.index_dir, "manifest.json")):
        raise SystemExit(
            f"--worker needs an existing v2 store at {args.index_dir}; "
            "build it first (any non-worker run with --store-format v2 "
            "--index-dir builds one)")
    nodes = (args.worker_nodes.split(",") if args.worker_nodes
             else [f"host{i}" for i in range(args.hosts)])
    if args.worker not in nodes:
        raise SystemExit(f"--worker {args.worker} is not in the node list "
                         f"{nodes} (pass --worker-nodes, identically on "
                         "every process)")
    placement = ShardPlacement.for_store(
        args.index_dir, nodes, replication=min(args.replication, len(nodes)))
    held = placement.replica_assignment()[args.worker]
    if not held:
        raise SystemExit(f"node {args.worker} holds no shards under this "
                         f"placement ({len(nodes)} nodes x "
                         f"{placement.n_shards} shards); nothing to serve")
    tile_bytes = (None if args.tile_cache_mib is None
                  else int(args.tile_cache_mib * 2**20))
    worker = ShardWorker(args.worker, args.index_dir, held,
                         tile_cache_bytes=tile_bytes,
                         word_block=args.word_block, pruned=args.prune,
                         prune_chunk=args.prune_chunk,
                         prune_min_rate=args.prune_min_rate)
    srv = WorkerServer(worker, host=args.listen_host,
                       port=args.worker_port,
                       straggle_s=args.straggle_ms / 1e3).start()
    host, port = srv.address
    if args.port_file:
        # atomic publish so a waiter never reads a torn file
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(f"{host} {port}\n")
        os.replace(tmp, args.port_file)
    print(f"worker {args.worker}: {len(held)} shard(s) {sorted(held)} "
          f"on {host}:{port} (wire v{PROTO_VERSION})", flush=True)
    try:
        while True:
            time.sleep(1.0)
    except KeyboardInterrupt:
        pass
    srv.close()


def _read_port_file(path: str, timeout_s: float) -> tuple[str, int]:
    """Wait for a worker's --port-file and return (host, port)."""
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        try:
            with open(path) as f:
                parts = f.read().split()
            if len(parts) == 2:
                return parts[0], int(parts[1])
        except (FileNotFoundError, ValueError):
            pass
        time.sleep(0.05)
    raise SystemExit(f"timed out after {timeout_s:.0f}s waiting for "
                     f"worker port file {path}")


def parse_worker_spec(spec: str, timeout_s: float = 30.0
                      ) -> dict[str, tuple[str, int]]:
    """--workers value -> {node: (host, port)}. Entries are comma-
    separated ``node=host:port``, or ``node=@portfile`` to read (and wait
    for) the --port-file a worker process publishes."""
    out: dict[str, tuple[str, int]] = {}
    for part in spec.split(","):
        name, eq, addr = part.strip().partition("=")
        if not (eq and name and addr):
            raise SystemExit(f"--workers entry {part!r}: expected "
                             "node=host:port or node=@portfile")
        if addr.startswith("@"):
            out[name] = _read_port_file(addr[1:], timeout_s)
        else:
            host, _, port = addr.rpartition(":")
            try:
                out[name] = (host or "127.0.0.1", int(port))
            except ValueError:
                raise SystemExit(
                    f"--workers entry {part!r}: bad port") from None
    return out


def make_rpc_frontend(store_dir, worker_addrs, *, replication: int,
                      max_batch: int, max_wait_s: float,
                      hedge_after_s: float, hedge_auto: bool = False,
                      scatter_threads: int = 4, tracing: bool = True,
                      trace_slow_ms: float = 0.0, trace_log=None,
                      pruned: bool = False, prune_chunk: int = 32,
                      adaptive_buckets: bool = False,
                      connect_timeout_s: float = 15.0):
    """Networked data plane: dial every worker process through the
    reconnecting channel pool and scatter per-shard dispatches as real
    RPCs — wall-clock hedged backups, CANCEL-on-win, replica failover."""
    from ..index import ShardPlacement
    from ..serve.rpc import RpcFrontend, WorkerPool

    nodes = list(worker_addrs)
    placement = ShardPlacement.for_store(
        store_dir, nodes, replication=min(replication, len(nodes)))
    pool = WorkerPool(worker_addrs)
    try:
        pool.wait_connected(timeout_s=connect_timeout_s)
    except TimeoutError as e:
        pool.close()
        raise SystemExit(str(e)) from None
    frontend = RpcFrontend(pool, placement, FrontendConfig(
        max_batch=max_batch, max_wait_s=max_wait_s,
        hedge_after_s=hedge_after_s, hedge_auto=hedge_auto,
        scatter_threads=scatter_threads, tracing=tracing,
        trace_slow_ms=trace_slow_ms, trace_log=trace_log,
        pruned=pruned, prune_chunk=prune_chunk,
        adaptive_buckets=adaptive_buckets))
    gaps = frontend.verify_placement()
    if gaps:
        print(f"warning: workers missing placement shards: {gaps} "
              "(check --worker-nodes / --replication match on every "
              "process)")
    return frontend


def load_bulk_patterns(path) -> list:
    """One query pattern per line; blank lines and # comments skipped."""
    patterns = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                patterns.append(line)
    if not patterns:
        raise SystemExit(f"--bulk {path}: no patterns")
    return patterns


def submit_bulk_file(lane, args, on_done=None):
    """Queue the --bulk FILE job (resuming from --bulk-checkpoint when
    the file already exists)."""
    resume = None
    if args.bulk_checkpoint and os.path.exists(args.bulk_checkpoint):
        from ..serve import BulkJob
        resume = BulkJob.load(args.bulk_checkpoint)
        print(f"resuming bulk sweep at shard {resume['next_shard']} "
              f"from {args.bulk_checkpoint}")
    threshold = (args.bulk_threshold if args.bulk_threshold is not None
                 else args.threshold)
    return lane.submit(load_bulk_patterns(args.bulk),
                       threshold=None if args.bulk_topk else threshold,
                       top_k=args.bulk_topk,
                       pruned=args.prune and not args.bulk_topk,
                       tag=os.path.basename(args.bulk), resume=resume,
                       checkpoint_path=args.bulk_checkpoint,
                       on_done=on_done)


def report_bulk(job) -> None:
    st = job.stats
    line = (f"bulk[{job.tag}] {job.status.value}: {job.n_queries} queries"
            f" x {st.shards_swept} shard sweeps in "
            f"{job.finished_at - job.started_at:.2f}s; staged "
            f"{st.bytes_staged / 2**20:.2f} MiB total = "
            f"{job.staged_bytes_per_query:.0f} B/query "
            f"({st.kernel_dispatches} dispatches)")
    if st.blocks_total:
        line += f"; prune rate {st.prune_rate:.0%}"
    if job.error:
        line += f"; error: {job.error}"
    print(line)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=256)
    ap.add_argument("--queries", type=int, default=160)
    ap.add_argument("--threshold", type=float, default=0.8)
    ap.add_argument("--mode", default="closed", choices=["closed", "open"])
    ap.add_argument("--concurrency", type=int, default=32,
                    help="closed-loop in-flight window")
    ap.add_argument("--qps", type=float, default=200.0,
                    help="open-loop offered load")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--index-dir", default=None,
                    help="load/save the index here")
    ap.add_argument("--store-format", default="v1", choices=["v1", "v2"],
                    help="on-disk format when building with --index-dir: "
                         "v2 streams shards and serves out-of-core (mmap)")
    ap.add_argument("--tile-cache-mib", type=float, default=None,
                    help="HBM budget for shard tiles when serving a "
                         "sharded (v2) index; default unbounded (per host "
                         "in multi-host mode)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="> 1 serves the v2 store through N in-process "
                         "fake hosts (ShardWorker + Frontend)")
    ap.add_argument("--replication", type=int, default=2,
                    help="replicas per shard in multi-host mode")
    ap.add_argument("--hedge-after-ms", default="50",
                    help="backup-request deadline per shard dispatch (ms),"
                         " or 'auto' to derive it from the observed "
                         "per-worker latency histogram p95 (adapts as "
                         "traffic flows). In-process dispatch is "
                         "synchronous, so wall-clock runs apply failover "
                         "only; backup requests fire in the simulated-"
                         "latency benches (benchmarks/serving.py "
                         "run_multihost)")
    ap.add_argument("--fail-host", action="append", default=[],
                    help="mark a host down before the run (repeatable), "
                         "e.g. --fail-host host1")
    ap.add_argument("--word-block", type=int, default=None,
                    help="kernel tile width for every scoring dispatch; "
                         "default: the autotuner's measured choice (with "
                         "--autotune / a tuning cache) else the kernel "
                         "default")
    ap.add_argument("--autotune", action="store_true",
                    help="measure kernel configs per batch shape on "
                         "demand and drive the planner from measured "
                         "costs; entries persist in the tuning cache "
                         "(tuning.json beside a v2 store's manifest). "
                         "Single-host mode only")
    ap.add_argument("--tuning-cache", default=None,
                    help="explicit tuning-cache path; default: "
                         "<index-dir>/tuning.json for v2 stores, "
                         "in-memory otherwise")
    ap.add_argument("--dedup-min-rate", type=float, default=0.5,
                    help="minimum batch row-dedup rate before the "
                         "unique-row scoring path replaces the fused "
                         "multi-query kernel; negative disables dedup "
                         "(a tuner-measured break-even overrides this). "
                         "Single-host mode only")
    ap.add_argument("--prune", action="store_true",
                    help="threshold-driven pruned scoring: execute terms "
                         "rarest-first in chunks and early-exit blocks "
                         "whose bound cannot reach the coverage cutoff, "
                         "skipping their tile I/O, staging and kernel "
                         "work. The planner still gates per batch on the "
                         "tuned/heuristic break-even; results stay "
                         "bit-identical. STATS show blocks pruned / tiles "
                         "skipped / bytes saved")
    ap.add_argument("--prune-chunk", type=int, default=32,
                    help="terms per chunk for --prune (smaller = earlier "
                         "exit, more dispatches)")
    ap.add_argument("--prune-min-rate", type=float, default=None,
                    help="minimum predicted block-prune rate before a "
                         "batch dispatches pruned (default 0.5; a "
                         "tuner-measured break-even overrides this)")
    ap.add_argument("--adaptive-buckets", action="store_true",
                    help="fit micro-batch bucket edges to the observed "
                         "term-length histogram instead of the fixed "
                         "term_pad grid (denser batches when query "
                         "lengths cluster between grid lines)")
    ap.add_argument("--bulk", default=None, metavar="FILE",
                    help="sweep the query patterns in FILE (one per "
                         "line, # comments) through the offline bulk "
                         "lane — shard-major, each tile staged once for "
                         "the whole set. Runs alongside network traffic "
                         "in --listen mode, inline after the load report "
                         "otherwise")
    ap.add_argument("--bulk-threshold", type=float, default=None,
                    help="coverage threshold for the --bulk job "
                         "(default: --threshold)")
    ap.add_argument("--bulk-topk", type=int, default=0,
                    help="top-k mode for the --bulk job (0 = threshold)")
    ap.add_argument("--bulk-checkpoint", default=None, metavar="PATH",
                    help="checkpoint the --bulk sweep here after every "
                         "shard; an existing file resumes the sweep")
    ap.add_argument("--scatter-threads", type=int, default=4,
                    help="multi-host concurrent scatter pool size "
                         "(<= 1 = sequential per-shard dispatch)")
    ap.add_argument("--worker", default=None, metavar="NAME",
                    help="process role: serve placement node NAME's shard "
                         "replicas over the v4 wire protocol "
                         "(WorkerServer) instead of generating load. "
                         "Needs an existing v2 store; pair with "
                         "--worker-nodes / --worker-port / --port-file")
    ap.add_argument("--worker-nodes", default=None, metavar="N0,N1,...",
                    help="full logical node list for the HRW placement; "
                         "must be identical on every worker and the "
                         "frontend (default: host0..host{--hosts-1})")
    ap.add_argument("--worker-port", type=int, default=0, metavar="PORT",
                    help="bind port for --worker (0 = OS-assigned; "
                         "published via --port-file)")
    ap.add_argument("--port-file", default=None, metavar="PATH",
                    help="--worker writes 'host port' here (atomically) "
                         "once bound — launchers/tests read it to "
                         "discover OS-assigned ports")
    ap.add_argument("--straggle-ms", type=float, default=0.0,
                    help="--worker only: sleep this long before every "
                         "dispatch (cancellation-aware) — an injected "
                         "straggler for hedging demos and benches")
    ap.add_argument("--workers", default=None,
                    metavar="N0=HOST:PORT,N1=@PORTFILE,...",
                    help="process role: frontend over the RPC data plane "
                         "— dial these worker processes through the "
                         "reconnecting channel pool and scatter every "
                         "shard dispatch as a real hedged RPC. "
                         "@portfile entries wait for a --port-file. "
                         "Combine with --listen for the TCP front door")
    ap.add_argument("--connect-timeout", type=float, default=15.0,
                    help="seconds to wait for --workers port files and "
                         "first connections")
    ap.add_argument("--listen", type=int, default=None, metavar="PORT",
                    help="serve over TCP instead of generating load: "
                         "active ServingLoop + wire protocol on this "
                         "port (0 = ephemeral). Query with "
                         "repro.serve.NetClient or benchmarks/serving.py "
                         "--listen. Ctrl-C drains in-flight batches and "
                         "exits")
    ap.add_argument("--listen-host", default="127.0.0.1",
                    help="bind address for --listen")
    ap.add_argument("--loop-workers", type=int, default=1,
                    help="scoring worker threads in the serving loop "
                         "(--listen mode)")
    ap.add_argument("--stats-interval", type=float, default=None,
                    metavar="SECONDS",
                    help="in --listen mode, dump the Prometheus text "
                         "exposition of the whole metrics registry every "
                         "SECONDS (besides the one-line snapshot report); "
                         "SIGUSR1 dumps it on demand either way")
    ap.add_argument("--no-trace", action="store_true",
                    help="disable request tracing (spans, trace ids on "
                         "the wire, the slow-query log)")
    ap.add_argument("--trace-slow-ms", type=float, default=0.0,
                    help="emit finished traces slower than this to the "
                         "slow-query event log (0 = off)")
    ap.add_argument("--trace-log", default=None, metavar="PATH",
                    help="append slow-query trace events as JSONL here "
                         "(replay with benchmarks/trace_report.py)")
    ap.add_argument("--no-warmup", action="store_true")
    args = ap.parse_args()
    if args.hedge_after_ms == "auto":
        hedge_after_ms, hedge_auto = 50.0, True
    else:
        try:
            hedge_after_ms, hedge_auto = float(args.hedge_after_ms), False
        except ValueError:
            ap.error("--hedge-after-ms takes a number of ms or 'auto'")
    if args.mode == "open" and args.qps <= 0:
        ap.error("--qps must be > 0 in open-loop mode")
    if args.store_format == "v2" and not args.index_dir:
        ap.error("--store-format v2 requires --index-dir (the store is "
                 "the on-disk shard directory)")
    if args.concurrency < 1:
        ap.error("--concurrency must be >= 1")
    if args.hosts > 1 and not (args.store_format == "v2" and args.index_dir):
        ap.error("--hosts > 1 requires --store-format v2 --index-dir (the "
                 "shard files are the placement unit)")
    if args.worker and args.workers:
        ap.error("--worker and --workers are mutually exclusive process "
                 "roles")
    if (args.worker or args.workers) and not (args.store_format == "v2"
                                              and args.index_dir):
        ap.error("--worker/--workers require --store-format v2 "
                 "--index-dir (the shard files are the placement unit)")
    if args.worker:
        run_worker(args)
        return

    corpus, index = build_or_load(args)
    tile_bytes = (None if args.tile_cache_mib is None
                  else int(args.tile_cache_mib * 2**20))
    tuning_cache = args.tuning_cache
    if tuning_cache is None and args.store_format == "v2" and args.index_dir:
        from ..core.store import tuning_path
        tuning_cache = str(tuning_path(args.index_dir))
    if args.workers:
        server = make_rpc_frontend(
            args.index_dir,
            parse_worker_spec(args.workers, args.connect_timeout),
            replication=args.replication, max_batch=args.max_batch,
            max_wait_s=args.max_wait_ms / 1e3,
            hedge_after_s=hedge_after_ms / 1e3, hedge_auto=hedge_auto,
            scatter_threads=args.scatter_threads,
            tracing=not args.no_trace, trace_slow_ms=args.trace_slow_ms,
            trace_log=args.trace_log, pruned=args.prune,
            prune_chunk=args.prune_chunk,
            adaptive_buckets=args.adaptive_buckets,
            connect_timeout_s=args.connect_timeout)
        print(f"rpc frontend: {len(server.placement.nodes)} worker "
              f"process(es), replication "
              f"{min(args.replication, len(server.placement.nodes))}, "
              f"{server.placement.n_shards} shards, hedge_after="
              f"{hedge_after_ms}ms")
    elif args.hosts > 1:
        if args.autotune or args.tuning_cache or args.dedup_min_rate != 0.5:
            print("note: --autotune/--tuning-cache/--dedup-min-rate apply "
                  "to the single-host QueryServer only; the multi-host "
                  "ShardWorkers take --word-block but keep heuristic "
                  "kernel choice (see ROADMAP open items)")
        server = make_multihost_frontend(
            args.index_dir, hosts=args.hosts, replication=args.replication,
            max_batch=args.max_batch, max_wait_s=args.max_wait_ms / 1e3,
            hedge_after_s=hedge_after_ms / 1e3, hedge_auto=hedge_auto,
            tile_cache_bytes=tile_bytes, word_block=args.word_block,
            scatter_threads=args.scatter_threads,
            fail_hosts=args.fail_host, tracing=not args.no_trace,
            trace_slow_ms=args.trace_slow_ms, trace_log=args.trace_log,
            pruned=args.prune, prune_chunk=args.prune_chunk,
            prune_min_rate=args.prune_min_rate,
            adaptive_buckets=args.adaptive_buckets)
        down = sorted(set(server.placement.nodes)
                      - set(server.placement.live_nodes))
        print(f"multi-host frontend: {args.hosts} hosts, "
              f"replication {min(args.replication, args.hosts)}, "
              f"{server.placement.n_shards} shards, down={down or 'none'}")
    else:
        server = QueryServer(index, ServerConfig(
            max_batch=args.max_batch, max_wait_s=args.max_wait_ms / 1e3,
            tile_cache_bytes=tile_bytes, word_block=args.word_block,
            dedup_min_rate=(None if args.dedup_min_rate < 0
                            else args.dedup_min_rate),
            autotune=args.autotune,
            tuning_cache=tuning_cache if args.autotune or args.tuning_cache
            else None,
            pruned=args.prune, prune_chunk=args.prune_chunk,
            prune_min_rate=args.prune_min_rate,
            tracing=not args.no_trace, trace_slow_ms=args.trace_slow_ms,
            trace_log=args.trace_log,
            adaptive_buckets=args.adaptive_buckets))
        if args.autotune:
            print(f"autotune on: cache="
                  f"{tuning_cache or 'in-memory'}")
    if args.listen is not None:
        # network serving mode: no local load generation — stand up the
        # active loop + wire protocol and serve until interrupted.
        import signal

        from ..obs.export import render_prometheus
        from ..serve import BulkLane, NetServer, ServingLoop
        from ..serve.net import PROTO_VERSION
        loop = ServingLoop(server, workers=args.loop_workers)
        # offline lane: BULK wire frames (and --bulk FILE) sweep in the
        # interactive lane's idle time, one shard per lock acquisition
        lane = BulkLane(server, loop).start()
        net = NetServer(loop, host=args.listen_host,
                        port=args.listen).start()
        host, port = net.address
        if args.bulk:
            job = submit_bulk_file(lane, args, on_done=report_bulk)
            print(f"bulk job {job.job_id} queued: {job.n_queries} "
                  f"queries from {args.bulk}")

        def dump_registry(*_sig) -> None:
            # registry metrics lock individually, so this is safe from
            # the signal handler / monitor thread while workers record
            print(render_prometheus(server.metrics.registry), end="")

        if hasattr(signal, "SIGUSR1"):
            signal.signal(signal.SIGUSR1, dump_registry)
            print("SIGUSR1 dumps the metrics registry "
                  f"(kill -USR1 {os.getpid()})")
        print(f"serving on {host}:{port} (wire protocol "
              f"v{PROTO_VERSION}; query with repro.serve.NetClient, or "
              f"drive load with python -m benchmarks.serving --listen "
              f"--connect {host}:{port})")
        interval = args.stats_interval or 10.0
        try:
            while True:
                time.sleep(interval)
                # snapshot under the loop lock: workers are appending to
                # the metric deques while this thread reads them
                print(loop.metrics_snapshot().report())
                if args.stats_interval:
                    dump_registry()
        except KeyboardInterrupt:
            print("draining in-flight batches ...")
        net.close(drain=True)
        print(server.metrics.snapshot().report())
        if args.workers:
            server.close()           # drop the worker channel pool
        return

    queries, origin = make_workload(corpus, args.queries)

    if args.mode == "closed":
        runner = lambda: run_closed(server, queries, args.threshold,
                                    args.concurrency)
    else:
        runner = lambda: run_open(server, queries, args.threshold, args.qps)

    if not args.no_warmup:
        # Replay the measured routine once so every (bucket, batch-shape)
        # jit entry the timed run hits is already compiled — closed-loop
        # batching is deterministic, so the shape sets match exactly.
        runner()
        server.pop_responses()
        server.reset_metrics(clear_caches=True)

    t0 = time.perf_counter()
    ids = runner()
    wall = time.perf_counter() - t0

    responses = server.pop_responses()
    correct = total = 0
    for rid, o in zip(ids, origin):
        r = responses.get(rid)
        if r is None or r.status != Status.OK:
            continue
        hit_ids = set(r.result.doc_ids.tolist())
        correct += (o in hit_ids) if o >= 0 else (len(hit_ids) == 0)
        total += 1
    snap = server.metrics.snapshot()
    print(f"mode={args.mode} served {snap.served} queries in {wall:.2f}s "
          f"-> {snap.served / wall:.0f} qps")
    print(snap.report())
    print(f"accuracy vs ground truth: {correct}/{total}")

    if args.bulk:
        # inline sweep: same lane, synchronous drain — the report's
        # B/query line is the staged-bytes win over the interactive path
        from ..serve import BulkLane
        lane = BulkLane(server)
        job = submit_bulk_file(lane, args)
        lane.drain()
        report_bulk(job)

    if args.workers:
        server.close()               # drop the worker channel pool


if __name__ == "__main__":
    main()
