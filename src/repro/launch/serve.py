"""Index-serving launcher (the paper's workload): build or load a COBS
index and serve batched approximate-matching queries.

    PYTHONPATH=src python -m repro.launch.serve --n-docs 256 --batches 10

Reports per-batch latency percentiles and validates results against the
ground-truth origin labels — the end-to-end driver for the 'serve a small
model with batched requests' deliverable (the paper is an index, so the
served artifact is the index).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import IndexParams, QueryEngine, build_compact, load_index, save_index
from ..data import make_corpus, make_queries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=256)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--query-len", type=int, default=100)
    ap.add_argument("--threshold", type=float, default=0.8)
    ap.add_argument("--method", default="vertical",
                    choices=["ref", "unpack", "vertical", "lookup"])
    ap.add_argument("--index-dir", default=None,
                    help="load/save the index here")
    args = ap.parse_args()

    corpus = make_corpus(args.n_docs, k=15, mean_length=2000, sigma=1.0,
                         seed=0)
    index = None
    if args.index_dir:
        try:
            index = load_index(args.index_dir)
            print(f"loaded index from {args.index_dir}")
        except FileNotFoundError:
            pass
    if index is None:
        t0 = time.time()
        index = build_compact(corpus.doc_terms,
                              IndexParams(n_hashes=1, fpr=0.3, kmer=15),
                              block_docs=64)
        print(f"built compact index: {index.n_docs} docs, "
              f"{index.size_bytes() / 2**20:.1f} MiB in {time.time()-t0:.1f}s")
        if args.index_dir:
            save_index(index, args.index_dir)

    eng = QueryEngine(index, method=args.method)
    lat, correct, total = [], 0, 0
    for b in range(args.batches):
        queries, origin = make_queries(
            corpus, n_pos=args.batch_size // 2, n_neg=args.batch_size // 2,
            length=args.query_len, seed=100 + b)
        t0 = time.perf_counter()
        results = eng.search_batch(queries, threshold=args.threshold)
        lat.append(time.perf_counter() - t0)
        for r, o in zip(results, origin):
            ids = set(r.doc_ids.tolist())
            correct += (o in ids) if o >= 0 else (len(ids) == 0)
            total += 1
    lat_ms = np.array(lat) * 1e3
    print(f"served {total} queries in {args.batches} batches "
          f"({args.batch_size}/batch, method={args.method})")
    print(f"batch latency ms: p50={np.percentile(lat_ms, 50):.1f} "
          f"p90={np.percentile(lat_ms, 90):.1f} max={lat_ms.max():.1f} "
          f"(first batch includes jit)")
    print(f"accuracy vs ground truth: {correct}/{total}")


if __name__ == "__main__":
    main()
