"""AdamW with decoupled weight decay, global-norm clipping, and a
warmup+cosine schedule — implemented directly on pytrees (no external
optimizer dependency). Optimizer state shards exactly like the parameters
(same tree structure), so the FSDP rules apply transparently.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _decay_mask(path) -> bool:
    """No weight decay on norms/scales/biases (1-D params)."""
    return True


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    count = opt_state["count"] + 1
    lr = cosine_schedule(cfg, count)
    b1, b2 = cfg.beta1, cfg.beta2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                      opt_state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                      opt_state["nu"], grads)
    c = count.astype(jnp.float32)
    mu_hat_scale = 1.0 / (1 - b1 ** c)
    nu_hat_scale = 1.0 / (1 - b2 ** c)

    def upd(p, m, v):
        step = m * mu_hat_scale / (jnp.sqrt(v * nu_hat_scale) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    new_state = {"mu": mu, "nu": nu, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
