"""train_step factory: cross-entropy + aux losses, value_and_grad, AdamW.

The returned step is a pure function
    (state, batch) -> (state, metrics)
suitable for jax.jit with in_shardings from the rule engine; the dry-run
lowers exactly this function against ShapeDtypeStructs.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import Model
from . import optim


class TrainState(NamedTuple):
    step: jnp.ndarray          # int32 []
    params: Any
    opt_state: Any
    rng: jnp.ndarray


def loss_fn(model: Model, params, batch):
    """batch: {"tokens": [B,S], "labels": [B,S] (-1 = masked), optional
    "enc_feats"/"vis_embeds" for the stub frontends}."""
    logits, aux = model.forward_train(
        params, batch["tokens"],
        enc_feats=batch.get("enc_feats"),
        vis_embeds=batch.get("vis_embeds"))
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    # Sharding-friendly CE: the [B, S, V] logits stay vocab-sharded over
    # "model" end to end. logsumexp reduces over the sharded vocab (psum of
    # [B, S] partials) and the label logit is extracted with a one-hot
    # einsum instead of take_along_axis (which would all-gather the full
    # logits — measured 26 GiB/chip of temp on phi4 x train_4k).
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)                      # [B, S]
    onehot = (jnp.arange(logits.shape[-1], dtype=jnp.int32)[None, None, :]
              == safe[..., None])
    label_logit = jnp.sum(logits * onehot, axis=-1)              # [B, S]
    nll = lse - label_logit
    denom = jnp.maximum(valid.sum(), 1)
    ce = jnp.where(valid, nll, 0.0).sum() / denom
    total = ce
    for v in aux.values():
        total = total + v
    metrics = {"loss": total, "ce": ce,
               "accuracy": (jnp.where(
                   valid, (logits.argmax(-1) == safe), False).sum() / denom)}
    for k, v in aux.items():
        metrics[k] = v
    return total, metrics


def make_init_state(model: Model, opt_cfg: optim.AdamWConfig):
    def init(rng) -> TrainState:
        params, _ = model.init(rng)
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          opt_state=optim.adamw_init(params),
                          rng=jax.random.fold_in(rng, 17))
    return init


def make_train_step(model: Model, opt_cfg: optim.AdamWConfig,
                    microbatches: int = 1):
    """microbatches > 1 enables gradient accumulation: the global batch is
    split along dim 0 and scanned, dividing activation memory by N at one
    optimizer step of identical math (exact when microbatches carry equal
    valid-token counts, which the step-indexed pipeline guarantees)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(model, p, batch), has_aux=True)(params)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if microbatches == 1:
            (loss, metrics), grads = grads_of(state.params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def body(carry, one):
                g_acc, m_acc = carry
                (_, m), g = grads_of(state.params, one)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            first = jax.tree.map(lambda x: x[0], mb)
            m0 = jax.eval_shape(lambda: grads_of(state.params, first)[0][1])
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
            (grads, msum), _ = jax.lax.scan(body, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, msum)
        params, opt_state, opt_metrics = optim.adamw_update(
            opt_cfg, grads, state.opt_state, state.params)
        metrics.update(opt_metrics)
        new_state = TrainState(step=state.step + 1, params=params,
                               opt_state=opt_state,
                               rng=jax.random.fold_in(state.rng, 1))
        return new_state, metrics
    return train_step
