from .optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from .step import TrainState, make_train_step, make_init_state, loss_fn

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "TrainState", "make_train_step", "make_init_state", "loss_fn"]
