"""English-text q-gram indexing (paper section 2.1: 'the data structure can
also be used for indexing q-grams from other domains such as English
text') — byte 4-grams over documents, approximate quote search.

    PYTHONPATH=src python examples/text_search.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import dna, theory
from repro.core.index import BitSlicedIndex, IndexParams, build_compact
from repro.kernels import ops
from repro.core import hashing

DOCS = [
    b"the quick brown fox jumps over the lazy dog and keeps running "
    b"through the quiet forest until dawn breaks over the hills",
    b"bloom filters trade a tunable false positive rate for dramatic "
    b"space savings which makes them ideal for approximate indexes",
    b"bit sliced signature indexes store one row per filter position so "
    b"a query only scans the rows its q grams hash to",
    b"compact layouts size each block of documents by its largest member "
    b"keeping the false positive rate constant across skewed corpora",
    b"sequencing archives double every eighteen months and searching "
    b"them requires indexes that scale beyond main memory",
]
Q = 4

params = IndexParams(n_hashes=1, fpr=0.3, kmer=Q)
doc_terms = [dna.unique_terms(dna.pack_qgrams_bytes(d, Q)) for d in DOCS]
index = build_compact(doc_terms, params, block_docs=32, row_align=64)
print(f"text index: {index.n_docs} docs, {index.size_bytes()} bytes")

from repro.core.query import make_score_fn

score = make_score_fn(1, "vertical")


def search(query: bytes, threshold: float = 0.7):
    terms = dna.unique_terms(dna.pack_qgrams_bytes(query, Q))
    padded = np.zeros((max(64, len(terms)), 2), np.uint32)
    padded[:len(terms)] = terms
    slots = score(index.arena, index.row_offset, index.block_width,
                  jnp.asarray(padded), jnp.int32(len(terms)))
    scores = np.asarray(slots)[np.asarray(index.doc_slot)]
    cut = max(1, int(np.ceil(threshold * len(terms))))
    hits = np.nonzero(scores >= cut)[0]
    return hits, scores, len(terms)


for query, expect in [
    (b"quick brown fox jumps", 0),
    (b"false positive rate", None),        # appears in docs 1 AND 3
    (b"bit sliced signature", 2),
    (b"completely unrelated xylophone zebra quartz", -1),
]:
    hits, scores, ell = search(query)
    shown = ", ".join(f"doc{h}({scores[h]}/{ell})" for h in hits)
    print(f"  {query.decode():48s} -> {shown or 'no hits'}")
    if expect == -1:
        assert len(hits) == 0
    elif expect is not None:
        assert expect in hits
print("OK")
