"""End-to-end serving example: build a compact index and push a mixed
query workload through the serving subsystem (shape-bucketed micro-batcher
+ kernel planner + caches), reporting latency percentiles and accuracy.

    PYTHONPATH=src python examples/serve_index.py
    PYTHONPATH=src python examples/serve_index.py --mode open --qps 200

Quickstart, in code:

    from repro.serve import QueryServer, ServerConfig
    server = QueryServer(index, ServerConfig(max_batch=32))
    rid = server.submit("ACGT...", threshold=0.8)
    server.drain()
    result = server.pop_responses()[rid].result   # SearchResult

(thin wrapper over `python -m repro.launch.serve` with example defaults)
"""
import sys

from repro.launch import serve

sys.argv = [sys.argv[0], "--n-docs", "256", "--queries", "128",
            "--mode", "closed", "--concurrency", "32"] + sys.argv[1:]
serve.main()
