"""End-to-end serving driver (the paper's kind of system = an index, so the
served artifact is the index): build a compact index over a few hundred
documents, then serve batched approximate-matching queries and report
latency percentiles + ground-truth accuracy.

    PYTHONPATH=src python examples/serve_index.py
(thin wrapper over `python -m repro.launch.serve` with example defaults)
"""
import sys

from repro.launch import serve

sys.argv = [sys.argv[0], "--n-docs", "256", "--batches", "8",
            "--batch-size", "32", "--query-len", "100",
            "--method", "vertical"] + sys.argv[1:]
serve.main()
