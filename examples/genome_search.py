"""End-to-end reproduction of the paper's experimental loop at laptop scale:

  1. synthesize a size-skewed microbial-like corpus (log-normal sizes),
  2. build BOTH indexes: ClaBS (classic, uniform width) and COBS (compact),
  3. compare sizes (Fig. 4), construction times (Table 2),
  4. run labeled query batches (Table 3) and verify: zero false negatives,
     single-k-mer FPR ~ prescribed, long-query FPR ~ Theorem 1.

    PYTHONPATH=src python examples/genome_search.py [n_docs]
"""
import sys
import time

import numpy as np

from repro.core import (IndexParams, QueryEngine, build_classic,
                        build_compact, theory)
from repro.data import make_corpus, make_queries

n_docs = int(sys.argv[1]) if len(sys.argv) > 1 else 200

print(f"== corpus: {n_docs} documents, log-normal sizes ==")
corpus = make_corpus(n_docs, k=15, mean_length=2000, sigma=1.0, seed=0)
counts = corpus.term_counts()
print(f"   k-mers/doc: min {counts.min()}, mean {counts.mean():.0f}, "
      f"max {counts.max()} (skew {counts.max() / counts.mean():.1f}x)")

params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)

t0 = time.time()
classic = build_classic(corpus.doc_terms, params)
t_classic = time.time() - t0
t0 = time.time()
compact = build_compact(corpus.doc_terms, params, block_docs=64)
t_compact = time.time() - t0
print(f"== build: classic {t_classic:.2f}s -> {classic.size_bytes()/2**20:.2f} MiB | "
      f"compact {t_compact:.2f}s -> {compact.size_bytes()/2**20:.2f} MiB "
      f"({classic.size_bytes()/compact.size_bytes():.2f}x smaller)")

for ell in (15, 100, 1000):
    queries, origin = make_queries(corpus, n_pos=20, n_neg=20,
                                   length=max(ell, 15), seed=ell)
    eng = QueryEngine(compact)
    t0 = time.time()
    results = eng.search_batch(queries, threshold=0.8)
    dt = time.time() - t0
    tp = fn = fp = 0
    for r, o in zip(results, origin):
        ids = set(r.doc_ids.tolist())
        if o >= 0:
            tp += o in ids
            fn += o not in ids
            fp += len(ids - {o})
        else:
            fp += len(ids)
    n_terms = max(ell, 15) - 15 + 1
    expect_fp = theory.query_fpr(n_terms, 0.3, 0.8) * n_docs * len(queries)
    print(f"   ell={ell:5d}: {len(queries)} queries in {dt:.2f}s | "
          f"TP {tp}/20, FN {fn} (must be 0), FP {fp} "
          f"(Theorem-1 expectation {expect_fp:.3g})")
    assert fn == 0, "false negatives are impossible by construction"

print("== single k-mer FPR check (paper Table 3 bottom) ==")
rng = np.random.default_rng(5)
universe = set()
for t in corpus.doc_terms:
    u = t[:, 0].astype(np.uint64) | (t[:, 1].astype(np.uint64) << np.uint64(32))
    universe |= set(u.tolist())
from repro.core import dna
eng = QueryEngine(compact)
hits = total = probes = 0
while probes < 200:
    kmer = rng.integers(0, 4, 15, dtype=np.uint8)
    t = dna.pack_kmers(kmer, 15)
    if (int(t[0, 0]) | (int(t[0, 1]) << 32)) in universe:
        continue
    probes += 1
    hits += int((eng.score_terms(t) >= 1).sum())
    total += n_docs
print(f"   measured FPR {hits/total:.3f} | analytic "
      f"{compact.expected_fpr().mean():.3f} | prescribed {params.fpr}")
print("OK")
