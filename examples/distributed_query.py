"""Distributed COBS on a simulated 8-chip mesh (pod=2, data=2, model=2):
documents sharded over ("pod","data"), Bloom rows over "model", psum'd
partial scores, distributed top-k — then verified bit-exact against the
single-device engine.

    PYTHONPATH=src python examples/distributed_query.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.core import IndexParams, QueryEngine, build_compact, dna
from repro.data import make_corpus, make_queries
from repro.index import BlockPlacement, DistributedIndex
from repro.launch.mesh import make_mesh

print(f"devices: {len(jax.devices())}")
corpus = make_corpus(96, k=15, mean_length=800, sigma=1.0, seed=3)
index = build_compact(corpus.doc_terms, IndexParams(kmer=15), block_docs=32,
                      row_align=64)
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
dist = DistributedIndex(index, mesh, doc_axes=("pod", "data"),
                        row_axis="model")
print(f"arena {dist.total_rows}x{dist.doc_words} words; "
      f"per-chip stripe {dist.row_stripe}x{dist.words_local}")

single = QueryEngine(index, method="ref")
queries, origin = make_queries(corpus, n_pos=8, n_neg=4, length=90, seed=9)

# full score vectors must match the single-device engine exactly
for q in queries[:4]:
    terms = dna.unique_terms(dna.pack_kmers(q, 15))
    np.testing.assert_array_equal(single.score_terms(terms),
                                  dist.scores_for(terms))
print("sharded scores == single-device scores (bit-exact)")

# distributed top-k search
results = dist.search_batch(list(queries), threshold=0.9, topk=8)
ok = sum((o in set(ids.tolist())) if o >= 0 else (len(ids) == 0)
         for (ids, _), o in zip(results, origin))
print(f"search_batch ground-truth agreement: {ok}/{len(queries)}")

# control plane: placement, failover, elasticity
place = BlockPlacement([f"pod{i}" for i in range(4)],
                       n_blocks=index.n_blocks, replication=2)
print("assignment:", {k: v for k, v in place.assignment().items()})
moved = place.fail("pod1")
print(f"pod1 failed -> {len(moved)} block(s) fail over, "
      f"coverage={place.is_covered()}")
moved = place.add_node("pod4")
print(f"scale-up pod4 -> {len(moved)} block(s) migrate")
print("OK")
