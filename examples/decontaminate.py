"""Training-data decontamination with COBS — the framework-level integration
of the paper's technique (DESIGN.md §Arch-applicability): before training an
LM, every evaluation document is checked for n-gram overlap against the
training corpus using the compact bit-sliced signature index. This is the
production use of exactly this data structure: one-sided error means NO
contaminated eval doc can slip through (no false negatives), and Theorem 1
bounds the false-alarm rate.

A decontamination sweep is the canonical OFFLINE workload: the whole eval
set is known up front and nobody is waiting on a p99. So the sweep runs
through the serving stack's bulk lane (``repro.serve.BulkLane``), which
inverts the interactive loop: instead of every micro-batch restaging every
shard tile through the bounded HBM cache, each tile is staged ONCE and the
entire eval set streams against it. The script runs the same query set
down both lanes and prints the headline number — arena bytes staged per
query — alongside the exactness guarantees.

    PYTHONPATH=src python examples/decontaminate.py
"""
import tempfile

import numpy as np

from repro.core import IndexParams, QueryEngine, dna, theory
from repro.index import build_compact_streaming
from repro.serve import BulkLane, QueryServer, ServerConfig

rng = np.random.default_rng(0)

# --- "training corpus": byte-level documents -------------------------------
train_docs = [rng.integers(0, 4, size=int(n), dtype=np.uint8)
              for n in np.exp(rng.normal(7.5, 1.0, size=300))]
params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
doc_terms = [dna.document_terms([d], params.kmer) for d in train_docs]

# A sharded on-disk store, served out-of-core: shard tiles move host->HBM
# through a bounded DeviceTileCache, which is what makes staging traffic —
# the thing the bulk lane exists to amortize — measurable and real.
store_dir = tempfile.mkdtemp(prefix="decontaminate_store_")
index, build_stats = build_compact_streaming(
    doc_terms, store_dir, params, block_docs=64, blocks_per_shard=1)
print(f"training-corpus index: {index.n_docs} docs, "
      f"{build_stats.n_shards} shards at {store_dir}")
engine = QueryEngine(index)

# --- eval set: clean docs + planted contamination ---------------------------
eval_docs, labels = [], []
for i in range(40):
    if i % 4 == 0:  # contaminated: verbatim span copied from training doc
        src = train_docs[int(rng.integers(0, len(train_docs)))]
        if len(src) < 400:
            src = np.concatenate([src] * 3)
        start = int(rng.integers(0, len(src) - 250))
        doc = np.concatenate([rng.integers(0, 4, 100, dtype=np.uint8),
                              src[start:start + 250],
                              rng.integers(0, 4, 100, dtype=np.uint8)])
        labels.append(True)
    else:
        doc = rng.integers(0, 4, 400, dtype=np.uint8)
        labels.append(False)
    eval_docs.append(doc)

TAU = 0.5    # fraction of the eval doc's n-grams found in ANY training doc

# --- interactive lane baseline: query-major, tiles restaged per batch ------
# The cache holds one shard tile at a time, so every micro-batch sweeping
# all shards evicts and restages — Q/B stagings per shard, the cost the
# bulk lane removes.
tile_bytes = max(index.storage.shard_nbytes(s)
                 for s in range(index.storage.n_shards))
server = QueryServer(index, ServerConfig(max_batch=8,
                                         tile_cache_bytes=tile_bytes))
rids = []
for i in range(0, len(eval_docs), 8):
    for d in eval_docs[i:i + 8]:
        rids.append(server.submit(d, threshold=TAU))
    server.drain()
inter_results = [server.pop_responses()[r].result for r in [rids[-1]]]
inter_staged = server.tiles.raw_bytes_staged + server.tiles.comp_bytes_staged
inter_per_q = inter_staged / len(eval_docs)

# --- decontamination sweep through the bulk lane ---------------------------
# Same backend, same tiles: the lane stages each shard once and streams
# the whole eval set against it (synchronous here — no serving loop, so
# submit + drain runs the sweep inline).
lane = BulkLane(server)
job = lane.submit(eval_docs, threshold=TAU, tag="decontaminate")
lane.drain()
assert job.status.value == "done", job.error
flagged = [len(r.doc_ids) > 0 for r in job.results]
bulk_per_q = job.staged_bytes_per_query

# --- exactness: bit-identical to the engine, one-sided error ----------------
for doc, res in zip(eval_docs[:8], job.results[:8]):
    oracle = engine.search(doc, threshold=TAU)
    assert (res.doc_ids == oracle.doc_ids).all()
    assert (res.scores == oracle.scores).all()

tp = sum(f and l for f, l in zip(flagged, labels))
fn = sum((not f) and l for f, l in zip(flagged, labels))
fp = sum(f and (not l) for f, l in zip(flagged, labels))
ell = 400 - params.kmer + 1
bound = theory.query_fpr(ell, params.fpr, TAU) * index.n_docs
print(f"eval docs: {len(eval_docs)} | contaminated: {sum(labels)}")
print(f"flagged: TP {tp}, FN {fn} (structurally 0 — one-sided error), "
      f"FP {fp} (Theorem-1 bound per clean doc: {bound:.2e})")
print(f"staged per query: interactive {inter_per_q:,.0f} B "
      f"-> bulk {bulk_per_q:,.0f} B "
      f"({inter_per_q / max(bulk_per_q, 1):.1f}x less HBM traffic)")
assert fn == 0
assert bulk_per_q < inter_per_q
print("OK: no contaminated document escapes the sweep")
