"""Training-data decontamination with COBS — the framework-level integration
of the paper's technique (DESIGN.md §Arch-applicability): before training an
LM, every evaluation document is checked for n-gram overlap against the
training corpus using the compact bit-sliced signature index. This is the
production use of exactly this data structure: one-sided error means NO
contaminated eval doc can slip through (no false negatives), and Theorem 1
bounds the false-alarm rate.

    PYTHONPATH=src python examples/decontaminate.py
"""
import numpy as np

from repro.core import IndexParams, QueryEngine, build_compact, dna, theory

rng = np.random.default_rng(0)

# --- "training corpus": byte-level documents -------------------------------
train_docs = [rng.integers(0, 4, size=int(n), dtype=np.uint8)
              for n in np.exp(rng.normal(7.5, 1.0, size=300))]
params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)
doc_terms = [dna.document_terms([d], params.kmer) for d in train_docs]
index = build_compact(doc_terms, params, block_docs=64)
print(f"training-corpus index: {index.n_docs} docs, "
      f"{index.size_bytes()/2**20:.2f} MiB")
engine = QueryEngine(index)

# --- eval set: clean docs + planted contamination ---------------------------
eval_docs, labels = [], []
for i in range(40):
    if i % 4 == 0:  # contaminated: verbatim span copied from training doc
        src = train_docs[int(rng.integers(0, len(train_docs)))]
        if len(src) < 400:
            src = np.concatenate([src] * 3)
        start = int(rng.integers(0, len(src) - 250))
        doc = np.concatenate([rng.integers(0, 4, 100, dtype=np.uint8),
                              src[start:start + 250],
                              rng.integers(0, 4, 100, dtype=np.uint8)])
        labels.append(True)
    else:
        doc = rng.integers(0, 4, 400, dtype=np.uint8)
        labels.append(False)
    eval_docs.append(doc)

# --- decontamination sweep: flag eval docs with >= tau n-gram coverage ------
TAU = 0.5    # fraction of the eval doc's n-grams found in ANY training doc
flagged = []
for doc in eval_docs:
    res = engine.search(doc, threshold=TAU)
    flagged.append(len(res.doc_ids) > 0)

tp = sum(f and l for f, l in zip(flagged, labels))
fn = sum((not f) and l for f, l in zip(flagged, labels))
fp = sum(f and (not l) for f, l in zip(flagged, labels))
ell = 400 - params.kmer + 1
bound = theory.query_fpr(ell, params.fpr, TAU) * index.n_docs
print(f"eval docs: {len(eval_docs)} | contaminated: {sum(labels)}")
print(f"flagged: TP {tp}, FN {fn} (structurally 0 — one-sided error), "
      f"FP {fp} (Theorem-1 bound per clean doc: {bound:.2e})")
assert fn == 0
print("OK: no contaminated document escapes the sweep")
