"""Quickstart: build a compact bit-sliced signature index over a few DNA
documents and run approximate substring queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import IndexParams, QueryEngine, build_compact, dna

# --- three tiny "documents" (e.g. assembled genomes) ----------------------
rng = np.random.default_rng(0)
genomes = [rng.integers(0, 4, size=n, dtype=np.uint8)
           for n in (600, 1500, 4000)]
params = IndexParams(n_hashes=1, fpr=0.3, kmer=15)   # paper defaults (k=31
doc_terms = [dna.document_terms([g], params.kmer) for g in genomes]  # scaled)

index = build_compact(doc_terms, params, block_docs=32, row_align=64)
print(f"index: {index.n_docs} docs, {index.n_blocks} block(s), "
      f"{index.size_bytes() / 1024:.1f} KiB")

engine = QueryEngine(index)                           # Pallas vertical kernel

# --- a query that is a real substring of document 1 ------------------------
query = genomes[1][200:320]
res = engine.search(query, threshold=0.8)
print(f"substring query: ell={res.n_terms} distinct 15-mers, "
      f"threshold={res.threshold}")
for doc, score in zip(res.doc_ids, res.scores):
    print(f"  doc{doc}: score {score}/{res.n_terms}")
assert res.doc_ids[0] == 1

# --- a mutated copy (approximate match) ------------------------------------
from repro.data import mutate
res = engine.search(mutate(rng, query, 0.03), threshold=0.5)
print(f"3%-mutated query still hits doc {res.doc_ids[0]} "
      f"(score {res.scores[0]}/{res.n_terms})")

# --- a random negative ------------------------------------------------------
res = engine.search(rng.integers(0, 4, 120, dtype=np.uint8), threshold=0.8)
print(f"random query: {len(res.doc_ids)} hits (expected 0)")

# --- out of core: the index never has to be in RAM --------------------------
# A BitSlicedIndex is layout (metadata) + storage (bytes). Streaming the
# build into a cobs-jax-v2 store writes one raw .npy shard per block group
# (peak host memory = one block group); loading it back gives a MappedArena
# whose shards are np.memmap'd and paged to the device per query — results
# are bit-identical to the in-memory index. Legacy v1 directories still
# load via the same load_index, and migrate_v1_to_v2 upgrades them.
import tempfile
from pathlib import Path

from repro.core import load_index
from repro.index import build_compact_streaming

store = Path(tempfile.mkdtemp()) / "cobs-v2"
streamed, stats = build_compact_streaming(
    doc_terms, store, params, block_docs=32, row_align=64)
print(f"v2 store: {stats.n_shards} shard(s), peak build memory "
      f"{stats.peak_block_bytes / 1024:.1f} KiB of "
      f"{stats.total_arena_bytes / 1024:.1f} KiB arena")

paged = QueryEngine(load_index(store))     # mmap-backed, pages per shard
res2 = paged.search(genomes[1][200:320], threshold=0.8)
assert res2.doc_ids[0] == 1
print(f"paged query matches in-memory: doc{res2.doc_ids[0]} "
      f"score {res2.scores[0]}/{res2.n_terms}")
# (with many documents the store splits into one shard per block group and
#  QueryEngine pages shard tiles through paged.tiles, an LRU device cache —
#  see tests/test_arena_store.py and benchmarks/outofcore.py)

# --- multi-host serving: place the shards over 3 fake hosts -----------------
# The v2 manifest row (shard file) is the placement unit: rendezvous
# hashing assigns each shard to `replication` hosts, every host opens a
# sub-store view of ONLY its shards (ShardWorker), and a Frontend scatters
# each micro-batch shard by shard — with hedged backup requests against
# stragglers — then gathers the per-host candidates into the exact same
# top-k the single-host engine would return. Killing a host just flips its
# shards to the surviving replicas.
from repro.index import ShardPlacement
from repro.serve import Frontend, FrontendConfig, ShardWorker

hosts = ["host0", "host1", "host2"]
place = ShardPlacement.for_store(store, hosts, replication=2)
held = place.replica_assignment()
workers = {h: ShardWorker(h, store, held[h], verify=True)  # hash-checked open
           for h in hosts if held[h]}
frontend = Frontend(workers, place,
                    FrontendConfig(max_batch=8, max_wait_s=0.0))
rid = frontend.submit(genomes[1][200:320], threshold=0.8)
frontend.drain()
res3 = frontend.pop_responses()[rid].result
assert res3.doc_ids[0] == 1 and np.array_equal(res3.scores, res2.scores)
print(f"sharded frontend over {place.n_shards} shard(s) x {len(hosts)} "
      f"hosts matches: doc{res3.doc_ids[0]} score {res3.scores[0]}")

down = place.owner(0)
frontend.fail_worker(down)                 # one host dies ...
rid = frontend.submit(genomes[1][200:320], top_k=3)
frontend.drain()
res4 = frontend.pop_responses()[rid].result
assert res4.doc_ids[0] == 1
print(f"with {down} down, replicas still answer: top-k doc{res4.doc_ids[0]} "
      f"(failovers={frontend.metrics.snapshot().failovers})")

# --- tune, then serve: measured kernel configs + the row-dedup path ---------
# The autotuner benchmarks word_block / term_block / grid order per batch
# shape and persists the winners in tuning.json BESIDE the store manifest;
# for the fused lookup kernel it also measures the dedup-rate break-even.
# Reopening the store serves straight from the cache — no re-tuning. Real
# query batches share rows heavily (overlapping k-mers), so when a batch's
# measured dedup rate clears the threshold the server swaps the fused
# multi-query kernel for the dedup pair: each unique arena row is streamed
# from HBM exactly ONCE, and every query scores against the resident copy.
from repro.core.store import tuning_path
from repro.serve import QueryServer, ServerConfig

server = QueryServer(load_index(store), ServerConfig(
    max_batch=8, max_wait_s=0.0,
    autotune=True,                          # measure misses on demand ...
    tuning_cache=str(tuning_path(store)),   # ... persist beside the manifest
    dedup_min_rate=0.5))                    # fallback threshold (untuned)
dup_batch = [genomes[1][200:320]] * 6       # heavy row overlap
rids = [server.submit(q, threshold=0.8) for q in dup_batch]
server.drain()
resp = server.pop_responses()
assert all(resp[r].result.doc_ids[0] == 1 for r in rids)
print(f"tuned server: dispatch mix {dict(server.planner.dispatch_counts)}, "
      f"tuning cache at {tuning_path(store).name} "
      f"({'exists' if tuning_path(store).exists() else 'missing'})")
# a reopened server consults the same cache and never re-measures:
#   QueryServer(load_index(store),
#               ServerConfig(tuning_cache=str(tuning_path(store))))

# --- serve it over the network ----------------------------------------------
# Everything above was in-process. The ServingLoop wraps the same server
# in an active dispatcher (flushes the micro-batcher on fill/wait-timer)
# plus scoring workers, and NetServer puts a length-prefixed binary wire
# protocol on a TCP port — so CONCURRENT independent clients coalesce
# into shared micro-batches, queue overflow answers a 429-style REJECTED
# instead of hanging, and close(drain=True) scores everything in flight
# before the socket goes down. NetClient learns the index parameters from
# the server's HELLO frame and compiles DNA patterns itself, so only
# packed terms cross the wire; results are bit-identical to the
# in-process engine, threshold and top-k alike.
from repro.serve import NetClient, NetServer, ServingLoop

net = NetServer(ServingLoop(QueryServer(load_index(store), ServerConfig(
    max_batch=8, max_wait_s=0.002)))).start()        # port 0 = ephemeral
host, port = net.address
with NetClient(host, port) as client:
    hit = client.search(genomes[1][200:320], threshold=0.8)
    top = client.top_k(genomes[1][200:320], k=2)
assert hit.result.doc_ids[0] == 1 and np.array_equal(hit.result.scores,
                                                     res2.scores)
assert top.result.doc_ids[0] == 1
net.close()                                           # graceful drain
print(f"network serving on {host}:{port}: doc{hit.result.doc_ids[0]} "
      f"score {hit.result.scores[0]}/{hit.result.n_terms} "
      f"(served by '{hit.method}' in a batch of {hit.batch_size}; "
      f"same bytes as the in-process engine)")
# a standalone server is one command:
#   python -m repro.launch.serve --listen 7070 --store-format v2 \
#       --index-dir /path/to/store
# and load against it:
#   python -m benchmarks.serving --listen --connect 127.0.0.1:7070

# --- observability: traces on the wire, metrics export, slow-query log ------
# Every admitted query gets a Trace; each serving layer appends spans
# (queue_wait, plan, kernel_score, shard_dispatch, gather, deliver...).
# The client mints the trace id, the RESULT frame carries the id + a
# per-stage timing breakdown back, and traces slower than trace_slow_ms
# land in a JSONL log that benchmarks/trace_report.py renders as an
# interval tree. The same registry behind the metrics serves a
# Prometheus text exposition over the STATS frame (and on SIGUSR1 /
# --stats-interval for the standalone launcher).
from repro.obs.events import read_jsonl

slow_log = store.parent / "slow.jsonl"
traced = QueryServer(load_index(store), ServerConfig(
    max_batch=8, max_wait_s=0.002,
    trace_slow_ms=0.001,                     # everything is "slow" here
    trace_log=str(slow_log)))
net = NetServer(ServingLoop(traced)).start()
with NetClient(*net.address) as client:
    r = client.search(genomes[1][200:320], threshold=0.8)
    stats = client.stats()                   # JSON snapshot over STATS
    prom = client.stats(prometheus=True)     # Prometheus text exposition
net.close()
stages = " ".join(f"{k}={v * 1e3:.2f}ms" for k, v in r.stages.items())
print(f"traced query {r.trace_id:#x}: {stages}")
print(f"stats: served={stats['served']} p99={stats['p99_ms']:.2f}ms; "
      f"prometheus exposition {len(prom.splitlines())} lines")
import time

for _ in range(100):                         # the loop seals the trace
    logged = [e for e in read_jsonl(slow_log)  # after delivering the
              if e.get("trace_id") == r.trace_id]  # RESULT frame
    if logged:
        break
    time.sleep(0.01)
assert logged, "the traced query must reach the slow-query log"
print(f"slow-query log has the matching span tree "
      f"({len(logged[0]['spans'])} spans) — render it with:\n"
      f"  python -m benchmarks.trace_report {slow_log}")

# --- compressed arena: fused-decode scoring ---------------------------------
# Real collections are redundant (strain panels, re-sequenced samples), so
# whole signature rows recur. codec="rowdict" (or "auto") stores each
# shard tile as (unique rows, int32 refs); the manifest records per-shard
# codec + ratio, hashes stay over the DECODED tile, and migrate_store_codec
# re-encodes existing stores in place-for-place geometry. A compressed
# engine/server keeps the (dict, refs) form in HBM — the working set
# shrinks by the ratio — and the Pallas kernels resolve refs inside the
# gather loop, so scores stay bit-identical to raw. The planner only picks
# the compressed path when the tuner's measured lookup_c cost (decode) is
# beaten by the bandwidth saved; ServerConfig(compressed=True) enables it.
dup_terms = [doc_terms[i % len(doc_terms)] for i in range(12)]  # redundant
comp_store = store.parent / "cobs-v2-comp"
# block_docs=128 -> 4-word tiles: rowdict needs multi-word rows to pay
comp_idx, comp_stats = build_compact_streaming(
    dup_terms, comp_store, params, block_docs=128, row_align=64,
    codec="rowdict")
ratio = comp_idx.storage.dict_ratio()
print(f"compressed store: ratio {ratio:.2f}x "
      f"({comp_stats.n_shards} shard(s), dict-coded HBM form)")

comp_server = QueryServer(comp_idx, ServerConfig(
    max_batch=8, max_wait_s=0.0, compressed=True))
rid = comp_server.submit(genomes[1][200:320], threshold=0.8)
comp_server.drain()
hit_c = comp_server.pop_responses()[rid].result
# docs 1, 4, 7, 10 are copies of genome 1 in the duplicated corpus: all
# hit, each with exactly the single-host score
assert 1 in hit_c.doc_ids and hit_c.scores.max() == res2.scores[0]
snap = comp_server.metrics.snapshot()
print(f"compressed serving: doc{hit_c.doc_ids[0]} "
      f"score {hit_c.scores[0]}/{hit_c.n_terms}, "
      f"HBM staged {snap.arena_comp_bytes}B compressed / "
      f"{snap.arena_raw_bytes}B raw "
      f"(plan compressed={comp_server.planner.plan(64, 8).compressed})")

# --- pruned scoring: the threshold becomes an I/O budget ---------------------
# A threshold query only reports documents covering >= ceil(thr * ell)
# terms — so once a block's running count plus its remaining term budget
# can't reach that bar, the executor stops reading its rows entirely.
# Terms run rarest-first (per-slice popcounts recorded in the v2
# manifest) in chunks; a (query, block) cell that falls behind is never
# gathered, staged, or scored again, and a fully-pruned shard performs
# ZERO tile fetches. Results stay bit-identical to exhaustive scoring —
# PruneStats just shows how much work the threshold bought back.
from repro.core import load_index as _load
from repro.core.query import PruneStats

prune_store = store.parent / "cobs-v2-prune"
wide_terms = [doc_terms[i % len(doc_terms)] for i in range(96)]
prune_idx, _ = build_compact_streaming(    # 32-doc blocks, one shard per
    wide_terms, prune_store, params, block_docs=32,  # block -> 3 tiles
    row_align=64, blocks_per_shard=1)                # to skip
prune_eng = QueryEngine(prune_idx, method="lookup", prune_chunk=16)
negative = rng.integers(0, 4, 150, dtype=np.uint8)  # matches nothing
pstats = PruneStats()
res_p = prune_eng.search_batch_pruned(
    [genomes[1][200:320], negative], threshold=1.0, stats=pstats)
res_x = QueryEngine(prune_idx, method="lookup").search_batch(
    [genomes[1][200:320], negative], threshold=1.0)
for a, b in zip(res_p, res_x):
    assert np.array_equal(a.doc_ids, b.doc_ids)
    assert np.array_equal(a.scores, b.scores)
total_b = sum(prune_idx.storage.shard_hbm_nbytes(s)
              for s in range(prune_idx.storage.n_shards))
print(f"pruned batch: {pstats.blocks_pruned}/{pstats.blocks_total} "
      f"(query, block) cells killed early, read {pstats.bytes_read}B of "
      f"{total_b}B arena ({total_b / max(1, pstats.bytes_read):.1f}x "
      f"less I/O, bit-identical, {prune_eng.tiles.faults} tile fetches)")

# the server gates pruning by a cost model (predicted prune rate vs the
# autotuned break-even) and exports the savings via STATS/Prometheus —
# look for the prune[...] section and serve_pruned_* counters
prune_server = QueryServer(prune_idx, ServerConfig(
    max_batch=8, max_wait_s=0.0, pruned=True, prune_chunk=16,
    prune_min_rate=0.05))
rid = prune_server.submit(negative, threshold=1.0)
prune_server.drain()
assert prune_server.pop_responses()[rid].result.doc_ids.size == 0
print(f"pruned serving: {prune_server.metrics.snapshot().report()}")
# the standalone launcher flag (STATS then shows tiles-skipped live):
#   python -m repro.launch.serve --listen 7070 --index-dir store --prune
