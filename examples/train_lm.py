"""Train a reduced-config LM for a few hundred steps on CPU with the full
production path: sharding rule engine (degenerate 1-device mesh), jit'd
train_step, async checkpointing, crash-safe resume.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen3-4b] [--steps 200]
(thin wrapper over `python -m repro.launch.train`)
"""
import sys

from repro.launch import train

args = sys.argv[1:]
if not any(a.startswith("--arch") for a in args):
    args = ["--arch", "qwen3-4b"] + args
if not any(a.startswith("--steps") for a in args):
    args += ["--steps", "200"]
sys.argv = [sys.argv[0], "--smoke", "--batch", "8", "--seq", "64",
            "--ckpt-dir", "/tmp/repro_train_lm"] + args
train.main()
